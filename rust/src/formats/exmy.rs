//! Generic eXmY floating-point formats (paper ref \[11\]: "eXmY: A Data
//! Type and Technique for Arbitrary Bit Precision Quantization").
//!
//! The paper evaluates e4m3, but its method — rank the symbol PMF,
//! partition into areas — applies to any 8-bit-or-smaller float
//! layout.  [`ExmyFormat`] builds the magnitude/boundary tables for any
//! `(exp_bits, man_bits)` split with `exp_bits + man_bits == 7` (one
//! sign bit, 256 symbols) or fewer total bits, enabling the
//! cross-format sweep in `benches/ablation_scheme.rs` and the e5m2 /
//! e3m4 comparisons.
//!
//! The e4m3 fast path in [`super::e4m3`] remains the default; this
//! module generalizes it (and its tests pin both to agree).

/// A sign + exponent + mantissa layout, all-finite (eXmY convention).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ExmySpec {
    pub exp_bits: u32,
    pub man_bits: u32,
}

impl ExmySpec {
    pub const E4M3: ExmySpec = ExmySpec { exp_bits: 4, man_bits: 3 };
    pub const E5M2: ExmySpec = ExmySpec { exp_bits: 5, man_bits: 2 };
    pub const E3M4: ExmySpec = ExmySpec { exp_bits: 3, man_bits: 4 };
    pub const E2M5: ExmySpec = ExmySpec { exp_bits: 2, man_bits: 5 };

    pub fn parse(s: &str) -> Option<ExmySpec> {
        let s = s.strip_prefix('e')?;
        let (e, m) = s.split_once('m')?;
        let spec = ExmySpec {
            exp_bits: e.parse().ok()?,
            man_bits: m.parse().ok()?,
        };
        (spec.total_bits() <= 8 && spec.exp_bits >= 1).then_some(spec)
    }

    pub fn name(&self) -> String {
        format!("e{}m{}", self.exp_bits, self.man_bits)
    }

    /// Sign + exponent + mantissa.
    pub fn total_bits(&self) -> u32 {
        1 + self.exp_bits + self.man_bits
    }

    /// Symbol alphabet size (≤ 256).
    pub fn num_symbols(&self) -> usize {
        1usize << self.total_bits()
    }

    /// IEEE-style bias: 2^(e-1) - 1.
    pub fn bias(&self) -> i32 {
        (1i32 << (self.exp_bits - 1)) - 1
    }
}

/// Precomputed tables for one eXmY format (all encodings finite).
#[derive(Clone, Debug)]
pub struct ExmyFormat {
    pub spec: ExmySpec,
    magnitudes: Vec<f32>,
    boundaries: Vec<f32>,
    max_finite: f32,
}

impl ExmyFormat {
    pub fn new(spec: ExmySpec) -> Self {
        assert!(spec.total_bits() <= 8, "symbols must fit one byte");
        assert!(spec.exp_bits >= 1);
        let half = spec.num_symbols() / 2;
        let man = spec.man_bits;
        let bias = spec.bias();
        let mut magnitudes = Vec::with_capacity(half);
        for i in 0..half {
            let e = (i as u32) >> man;
            let m = (i as u32) & ((1 << man) - 1);
            let v = if e == 0 {
                m as f64 * 2f64.powi(1 - bias - man as i32)
            } else {
                (1.0 + m as f64 / (1u64 << man) as f64)
                    * 2f64.powi(e as i32 - bias)
            };
            magnitudes.push(v as f32);
        }
        let boundaries: Vec<f32> = magnitudes
            .windows(2)
            .map(|w| ((w[0] as f64 + w[1] as f64) / 2.0) as f32)
            .collect();
        let max_finite = *magnitudes.last().unwrap();
        ExmyFormat { spec, magnitudes, boundaries, max_finite }
    }

    pub fn max_finite(&self) -> f32 {
        self.max_finite
    }

    pub fn magnitudes(&self) -> &[f32] {
        &self.magnitudes
    }

    /// Nearest-magnitude index with ties-to-even (the shared rule).
    pub fn magnitude_index(&self, mag: f32) -> u8 {
        let b = &self.boundaries;
        let mut lo = 0usize;
        let mut hi = b.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if b[mid] < mag {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        let tie = b.get(lo).map(|&x| x == mag).unwrap_or(false);
        let idx = if tie && lo % 2 == 1 { lo + 1 } else { lo };
        idx as u8
    }

    /// Quantize one value under a block scale.
    pub fn encode_scaled(&self, x: f32, inv_scale: f32) -> u8 {
        let mag = (x.abs() * inv_scale).min(self.max_finite);
        let idx = self.magnitude_index(mag);
        let sign = if x < 0.0 {
            (self.spec.num_symbols() / 2) as u8
        } else {
            0
        };
        sign | idx
    }

    /// Decode a symbol to its value (unscaled).
    pub fn decode(&self, symbol: u8) -> f32 {
        let half = self.spec.num_symbols() / 2;
        let idx = (symbol as usize) % half;
        let v = self.magnitudes[idx];
        if (symbol as usize) >= half {
            -v
        } else {
            v
        }
    }

    /// Quantize a whole tensor with block-32 absmax scaling; returns
    /// (symbols, scales).  Mirrors `BlockQuantizer` for e4m3.
    pub fn quantize_blocks(&self, data: &[f32]) -> (Vec<u8>, Vec<f32>) {
        assert!(data.len() % 32 == 0);
        let inv_max = 1.0 / self.max_finite;
        let mut symbols = vec![0u8; data.len()];
        let mut scales = vec![0f32; data.len() / 32];
        for (b, chunk) in data.chunks_exact(32).enumerate() {
            let absmax = chunk.iter().fold(0f32, |a, &x| a.max(x.abs()));
            let scale = if absmax > 0.0 { absmax * inv_max } else { 1.0 };
            scales[b] = scale;
            let inv_scale = 1.0 / scale;
            for (o, &x) in symbols[b * 32..].iter_mut().zip(chunk) {
                *o = self.encode_scaled(x, inv_scale);
            }
        }
        (symbols, scales)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::e4m3::{E4m3, Variant};
    use crate::util::rng::Rng;

    #[test]
    fn e4m3_matches_dedicated_implementation() {
        let gen = ExmyFormat::new(ExmySpec::E4M3);
        let dedicated = E4m3::new(Variant::ExmY);
        assert_eq!(gen.max_finite(), dedicated.max_finite());
        for i in 0..128usize {
            assert_eq!(
                gen.magnitudes()[i],
                dedicated.magnitudes()[i],
                "magnitude {i}"
            );
        }
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = (rng.normal() * 100.0) as f32;
            assert_eq!(
                gen.encode_scaled(x, 1.0),
                dedicated.encode_scaled(x, 1.0),
                "x={x}"
            );
        }
    }

    #[test]
    fn e5m2_properties() {
        let f = ExmyFormat::new(ExmySpec::E5M2);
        // max = 1.75 * 2^(31-15) = 114688? bias 15, top exp 31:
        // (1 + 3/4) * 2^16 = 114688.
        assert_eq!(f.max_finite(), 114_688.0);
        assert_eq!(f.spec.num_symbols(), 256);
        // min subnormal = 2^(1-15-2) = 2^-16.
        assert_eq!(f.magnitudes()[1], 2.0f32.powi(-16));
    }

    #[test]
    fn e3m4_properties() {
        let f = ExmyFormat::new(ExmySpec::E3M4);
        // bias 3, top exp 7, max = (1 + 15/16) * 2^4 = 31.
        assert_eq!(f.max_finite(), 31.0);
    }

    #[test]
    fn parse_names() {
        assert_eq!(ExmySpec::parse("e4m3"), Some(ExmySpec::E4M3));
        assert_eq!(ExmySpec::parse("e5m2"), Some(ExmySpec::E5M2));
        assert_eq!(ExmySpec::parse("e9m9"), None);
        assert_eq!(ExmySpec::parse("m3e4"), None);
        assert_eq!(ExmySpec::E2M5.name(), "e2m5");
    }

    #[test]
    fn decode_inverts_exact_codes() {
        for spec in [ExmySpec::E4M3, ExmySpec::E5M2, ExmySpec::E3M4] {
            let f = ExmyFormat::new(spec);
            for s in 0..spec.num_symbols() as u16 {
                let v = f.decode(s as u8);
                let re = f.encode_scaled(v, 1.0);
                // -0 encodes as +0's negative twin; allow sign-of-zero.
                if v == 0.0 {
                    assert_eq!(re & 0x7F, 0, "{}", spec.name());
                } else {
                    assert_eq!(re, s as u8, "{} symbol {s}", spec.name());
                }
            }
        }
    }

    #[test]
    fn block_quantize_all_formats() {
        let mut rng = Rng::new(5);
        let mut data = vec![0f32; 64 * 32];
        rng.fill_normal_f32(&mut data, 0.0, 2.0);
        for spec in [ExmySpec::E4M3, ExmySpec::E5M2, ExmySpec::E3M4,
                     ExmySpec::E2M5] {
            let f = ExmyFormat::new(spec);
            let (symbols, scales) = f.quantize_blocks(&data);
            assert_eq!(symbols.len(), data.len());
            assert_eq!(scales.len(), data.len() / 32);
            // Dequantized error bounded by one mantissa step.
            let step = 2.0f32.powi(-(spec.man_bits as i32));
            for (b, chunk) in data.chunks_exact(32).enumerate() {
                for (i, &x) in chunk.iter().enumerate() {
                    let y = f.decode(symbols[b * 32 + i]) * scales[b];
                    let tol = (x.abs() * step)
                        .max(scales[b] * f.magnitudes()[1] * 1.001);
                    assert!((x - y).abs() <= tol, "{}: {x} vs {y}",
                            spec.name());
                }
            }
        }
    }

    #[test]
    fn mantissa_rich_formats_have_higher_entropy() {
        // More mantissa bits spread symbols more evenly → higher
        // entropy → less to gain from entropy coding (context for the
        // paper's e4m3 focus).
        use crate::stats::Histogram;
        let mut rng = Rng::new(9);
        let mut data = vec![0f32; 2048 * 32];
        rng.fill_normal_f32(&mut data, 0.0, 1.0);
        let entropy = |spec: ExmySpec| {
            let f = ExmyFormat::new(spec);
            let (symbols, _) = f.quantize_blocks(&data);
            Histogram::from_symbols(&symbols).pmf().entropy()
        };
        let e5m2 = entropy(ExmySpec::E5M2);
        let e4m3 = entropy(ExmySpec::E4M3);
        let e3m4 = entropy(ExmySpec::E3M4);
        assert!(e5m2 < e4m3, "{e5m2} vs {e4m3}");
        assert!(e4m3 < e3m4, "{e4m3} vs {e3m4}");
    }
}
