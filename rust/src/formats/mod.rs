//! Numeric formats: the e4m3 data type (eXmY and OCP variants) and the
//! block-scaled quantizer that turns f32 tensors into the byte-symbol
//! streams the paper compresses.

pub mod e4m3;
pub mod exmy;
pub mod quantizer;

pub use e4m3::{E4m3, Variant};
pub use exmy::{ExmyFormat, ExmySpec};
pub use quantizer::{BlockQuantizer, QuantizedBlocks, BLOCK};
