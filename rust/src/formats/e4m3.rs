//! The e4m3 8-bit floating-point format.
//!
//! Symbol byte layout: `sign(1) | exponent(4) | mantissa(3)`, bias 7.
//! `exp == 0` encodes subnormals `m * 2^-9`; otherwise
//! `(1 + m/8) * 2^(exp-7)`.
//!
//! Two variants (paper §3):
//! * [`Variant::ExmY`] — the eXmY e4m3 the paper uses: **all 256
//!   encodings are finite**, max magnitude `1.875 * 2^8 = 480`.
//! * [`Variant::Ocp`] — OCP MX e4m3: `S.1111.111` is NaN, max 448.
//!
//! These tables are mirrored bit-for-bit by
//! `python/compile/kernels/e4m3.py`; the golden tests below match
//! `python/tests/test_e4m3.py`.

pub const SIGN_BIT: u8 = 0x80;
pub const MAN_BITS: u32 = 3;
pub const BIAS: i32 = 7;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    /// eXmY: all 256 encodings finite (paper default).
    ExmY,
    /// OCP MX: 0x7F / 0xFF are NaN.
    Ocp,
}

impl Variant {
    pub fn name(&self) -> &'static str {
        match self {
            Variant::ExmY => "exmy",
            Variant::Ocp => "ocp",
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "exmy" => Some(Variant::ExmY),
            "ocp" => Some(Variant::Ocp),
            _ => None,
        }
    }
}

/// Precomputed tables for one e4m3 variant.
#[derive(Clone, Debug)]
pub struct E4m3 {
    pub variant: Variant,
    /// 128 non-negative magnitudes by low-7-bit code; NaN slot = +inf
    /// (never selected by the quantizer).
    magnitudes: [f32; 128],
    /// Decision midpoints between consecutive finite magnitudes.
    boundaries: Vec<f32>,
    /// All 256 symbol values (0x80 = -0.0); OCP NaNs are f32::NAN.
    values: [f32; 256],
    max_finite: f32,
}

impl E4m3 {
    pub fn new(variant: Variant) -> Self {
        let mut magnitudes = [0f32; 128];
        for (i, m) in magnitudes.iter_mut().enumerate() {
            let e = (i as u32) >> MAN_BITS;
            let man = (i as u32) & ((1 << MAN_BITS) - 1);
            *m = if e == 0 {
                // Subnormal: m * 2^(1 - bias - man_bits) = m * 2^-9
                man as f32 * (2.0f32).powi(1 - BIAS - MAN_BITS as i32)
            } else {
                (1.0 + man as f32 / 8.0) * (2.0f32).powi(e as i32 - BIAS)
            };
        }
        if variant == Variant::Ocp {
            magnitudes[127] = f32::INFINITY;
        }
        let finite: Vec<f32> = magnitudes
            .iter()
            .copied()
            .filter(|m| m.is_finite())
            .collect();
        let boundaries: Vec<f32> = finite
            .windows(2)
            .map(|w| ((w[0] as f64 + w[1] as f64) / 2.0) as f32)
            .collect();
        let max_finite = *finite.last().unwrap();
        let mut values = [0f32; 256];
        for i in 0..128 {
            let v = if magnitudes[i].is_infinite() {
                f32::NAN
            } else {
                magnitudes[i]
            };
            values[i] = v;
            values[i + 128] = -v;
        }
        E4m3 { variant, magnitudes, boundaries, values, max_finite }
    }

    /// Largest finite magnitude (480 eXmY, 448 OCP).
    #[inline]
    pub fn max_finite(&self) -> f32 {
        self.max_finite
    }

    /// Value of a symbol byte (NaN for OCP NaN codes).
    #[inline]
    pub fn decode(&self, symbol: u8) -> f32 {
        self.values[symbol as usize]
    }

    /// All 256 symbol values.
    pub fn values(&self) -> &[f32; 256] {
        &self.values
    }

    /// Non-negative magnitude table (index = low 7 bits).
    pub fn magnitudes(&self) -> &[f32; 128] {
        &self.magnitudes
    }

    pub fn boundaries(&self) -> &[f32] {
        &self.boundaries
    }

    /// Quantize a non-negative magnitude (already scaled into the e4m3
    /// range) to the nearest magnitude index.  Exact midpoints resolve
    /// to the even index — the same rule as the Pallas kernel and the
    /// jnp oracle, so all three implementations are bit-identical.
    ///
    /// This is the scalar fallback; the hot path lives in
    /// [`crate::formats::quantizer`].
    #[inline]
    pub fn magnitude_index(&self, mag: f32) -> u8 {
        debug_assert!(mag >= 0.0);
        // Binary search: count of boundaries strictly below `mag`.
        let b = &self.boundaries;
        let mut lo = 0usize;
        let mut hi = b.len();
        while lo < hi {
            let mid = (lo + hi) / 2;
            if b[mid] < mag {
                lo = mid + 1;
            } else {
                hi = mid;
            }
        }
        // lo = #{b_i < mag}.  If mag equals boundary b_lo exactly the
        // "greater than" count is lo; tie → even index.
        let gt = lo;
        let tie = b.get(lo).map(|&x| x == mag).unwrap_or(false);
        let idx = if tie && gt % 2 == 1 { gt + 1 } else { gt };
        idx as u8
    }

    /// Encode one value given a block scale. Symbol = sign | mag index.
    #[inline]
    pub fn encode_scaled(&self, x: f32, inv_scale: f32) -> u8 {
        let mag = (x.abs() * inv_scale).min(self.max_finite);
        let idx = self.magnitude_index(mag);
        let sign = if x < 0.0 { SIGN_BIT } else { 0 };
        sign | idx
    }

    /// True if `symbol` is a NaN encoding in this variant.
    #[inline]
    pub fn is_nan_code(&self, symbol: u8) -> bool {
        self.variant == Variant::Ocp && (symbol & 0x7F) == 0x7F
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn exmy() -> E4m3 {
        E4m3::new(Variant::ExmY)
    }

    // Golden values mirrored in python/tests/test_e4m3.py.
    #[test]
    fn golden_magnitudes() {
        let t = exmy();
        let m = t.magnitudes();
        assert_eq!(m[0], 0.0);
        assert_eq!(m[1], 0.001953125); // 2^-9
        assert_eq!(m[7], 7.0 * 2.0f32.powi(-9));
        assert_eq!(m[8], 2.0f32.powi(-6)); // min normal
        assert_eq!(m[0x38], 1.0);
        assert_eq!(m[0x08], 0.015625);
        assert_eq!(m[0x0F], 0.029296875);
        assert_eq!(m[0x30], 0.5);
        assert_eq!(m[0x3C], 1.5);
        assert_eq!(m[0x40], 2.0);
        assert_eq!(m[0x7F], 480.0);
    }

    #[test]
    fn max_finite_per_variant() {
        assert_eq!(exmy().max_finite(), 480.0);
        assert_eq!(E4m3::new(Variant::Ocp).max_finite(), 448.0);
    }

    #[test]
    fn strictly_increasing() {
        let t = exmy();
        for w in t.magnitudes().windows(2) {
            assert!(w[0] < w[1]);
        }
    }

    #[test]
    fn boundary_counts() {
        assert_eq!(exmy().boundaries().len(), 127);
        assert_eq!(E4m3::new(Variant::Ocp).boundaries().len(), 126);
    }

    #[test]
    fn first_boundary() {
        assert_eq!(exmy().boundaries()[0], 2.0f32.powi(-10));
    }

    #[test]
    fn decode_signs() {
        let t = exmy();
        assert_eq!(t.decode(0x38), 1.0);
        assert_eq!(t.decode(0xB8), -1.0);
        assert_eq!(t.decode(0), 0.0);
        assert_eq!(t.decode(0x80), 0.0);
        assert!(t.decode(0x80).is_sign_negative());
        assert_eq!(t.decode(0x7F), 480.0);
        assert_eq!(t.decode(0xFF), -480.0);
    }

    #[test]
    fn ocp_nan_codes() {
        let t = E4m3::new(Variant::Ocp);
        assert!(t.decode(0x7F).is_nan());
        assert!(t.decode(0xFF).is_nan());
        assert!(t.is_nan_code(0x7F));
        assert!(t.is_nan_code(0xFF));
        assert!(!t.is_nan_code(0x7E));
        assert!(!exmy().is_nan_code(0x7F));
    }

    #[test]
    fn magnitude_index_nearest() {
        let t = exmy();
        // Exactly representable values map to themselves.
        for i in 0..128u8 {
            let m = t.magnitudes()[i as usize];
            assert_eq!(t.magnitude_index(m), i, "idx {i}");
        }
    }

    #[test]
    fn magnitude_index_rounds_to_nearest() {
        let t = exmy();
        let m = t.magnitudes();
        // Slightly above v[10] stays at 10; nearer v[11] goes to 11.
        let v10 = m[10];
        let v11 = m[11];
        assert_eq!(t.magnitude_index(v10 + (v11 - v10) * 0.25), 10);
        assert_eq!(t.magnitude_index(v10 + (v11 - v10) * 0.75), 11);
    }

    #[test]
    fn tie_goes_to_even() {
        let t = exmy();
        // boundary between idx 0 and 1 is 2^-10 → even idx 0.
        assert_eq!(t.magnitude_index(2.0f32.powi(-10)), 0);
        // boundary between idx 1 and 2 (0.001953125, 0.00390625) midpoint
        // = 0.0029296875 → even idx 2.
        let b = t.boundaries()[1];
        assert_eq!(t.magnitude_index(b), 2);
    }

    #[test]
    fn encode_scaled_clamps() {
        let t = exmy();
        assert_eq!(t.encode_scaled(1e30, 1.0), 0x7F);
        assert_eq!(t.encode_scaled(-1e30, 1.0), 0xFF);
    }

    #[test]
    fn encode_scaled_signs() {
        let t = exmy();
        assert_eq!(t.encode_scaled(1.0, 1.0), 0x38);
        assert_eq!(t.encode_scaled(-1.0, 1.0), 0xB8);
        assert_eq!(t.encode_scaled(0.0, 1.0), 0x00);
        // Negative zero / tiny negatives keep the sign bit.
        assert_eq!(t.encode_scaled(-1e-12, 1.0), 0x80);
    }

    #[test]
    fn ocp_never_emits_nan_code() {
        let t = E4m3::new(Variant::Ocp);
        assert_eq!(t.encode_scaled(1e30, 1.0), 0x7E); // clamps to 448
        assert_eq!(t.decode(0x7E), 448.0);
    }

    #[test]
    fn variant_parse() {
        assert_eq!(Variant::parse("exmy"), Some(Variant::ExmY));
        assert_eq!(Variant::parse("ocp"), Some(Variant::Ocp));
        assert_eq!(Variant::parse("e5m2"), None);
        assert_eq!(Variant::ExmY.name(), "exmy");
    }
}
