//! Block-scaled e4m3 quantization — the Rust mirror of the Pallas
//! kernel (`python/compile/kernels/quantize.py`) and the jnp oracle.
//!
//! Rule (paper §3: block size 32, absmax scaling):
//! 1. `scale = absmax(block) * (1 / MAX_FINITE)` (explicit reciprocal-
//!    multiply so XLA / numpy / Rust round identically; 1.0 for an
//!    all-zero block);
//! 2. `idx = nearest-boundary(|x| / scale)`, exact midpoints to the
//!    even index;
//! 3. `symbol = sign << 7 | idx`.
//!
//! Integration tests assert bit-identity against symbols produced by
//! the AOT-compiled Pallas kernel through the PJRT runtime.

use super::e4m3::{E4m3, Variant};

/// The paper's quantization block size.
pub const BLOCK: usize = 32;

/// Result of quantizing a tensor: one scale per 32-element block.
#[derive(Clone, Debug, PartialEq)]
pub struct QuantizedBlocks {
    pub symbols: Vec<u8>,
    pub scales: Vec<f32>,
    pub variant: Variant,
}

impl QuantizedBlocks {
    pub fn num_blocks(&self) -> usize {
        self.scales.len()
    }

    pub fn len(&self) -> usize {
        self.symbols.len()
    }

    pub fn is_empty(&self) -> bool {
        self.symbols.is_empty()
    }
}

/// Block quantizer with precomputed tables.
#[derive(Clone, Debug)]
pub struct BlockQuantizer {
    table: E4m3,
    inv_max: f32,
}

impl BlockQuantizer {
    pub fn new(variant: Variant) -> Self {
        let table = E4m3::new(variant);
        let inv_max = 1.0 / table.max_finite();
        BlockQuantizer { table, inv_max }
    }

    pub fn table(&self) -> &E4m3 {
        &self.table
    }

    /// Quantize `data` (length must be a multiple of [`BLOCK`]).
    pub fn quantize(&self, data: &[f32]) -> QuantizedBlocks {
        assert!(
            data.len() % BLOCK == 0,
            "tensor length {} not a multiple of block size {BLOCK}",
            data.len()
        );
        let num_blocks = data.len() / BLOCK;
        let mut symbols = vec![0u8; data.len()];
        let mut scales = vec![0f32; num_blocks];
        for (b, chunk) in data.chunks_exact(BLOCK).enumerate() {
            let mut absmax = 0f32;
            for &x in chunk {
                absmax = absmax.max(x.abs());
            }
            // Reciprocal-multiply, matching XLA's constant-division
            // rewrite (see quantize.py).
            let scale = if absmax > 0.0 { absmax * self.inv_max } else { 1.0 };
            scales[b] = scale;
            let inv_scale = 1.0 / scale;
            let out = &mut symbols[b * BLOCK..(b + 1) * BLOCK];
            for (o, &x) in out.iter_mut().zip(chunk) {
                *o = self.table.encode_scaled(x, inv_scale);
            }
        }
        QuantizedBlocks { symbols, scales, variant: self.table.variant }
    }

    /// Dequantize back to f32 (lossy — returns grid values).
    pub fn dequantize(&self, q: &QuantizedBlocks) -> Vec<f32> {
        assert_eq!(q.symbols.len(), q.scales.len() * BLOCK);
        let mut out = vec![0f32; q.symbols.len()];
        for (b, chunk) in q.symbols.chunks_exact(BLOCK).enumerate() {
            let scale = q.scales[b];
            for (o, &s) in out[b * BLOCK..].iter_mut().zip(chunk) {
                let v = self.table.decode(s);
                *o = if v.is_nan() { v } else { v * scale };
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::e4m3::SIGN_BIT;
    use crate::util::prop;
    use crate::util::rng::Rng;

    fn q() -> BlockQuantizer {
        BlockQuantizer::new(Variant::ExmY)
    }

    #[test]
    fn all_zero_block() {
        let qb = q().quantize(&[0.0; BLOCK]);
        assert!(qb.symbols.iter().all(|&s| s == 0));
        assert_eq!(qb.scales, vec![1.0]);
    }

    #[test]
    fn absmax_maps_to_top_code() {
        let mut data = [0f32; BLOCK];
        data[5] = -3.25;
        let qb = q().quantize(&data);
        assert_eq!(qb.symbols[5], SIGN_BIT | 0x7F);
        assert_eq!(qb.scales[0], 3.25f32 * (1.0 / 480.0));
    }

    #[test]
    fn extreme_dynamic_range_flushes_to_zero() {
        let mut data = [1e-10f32; BLOCK];
        data[0] = 1e30;
        let qb = q().quantize(&data);
        assert_eq!(qb.symbols[0], 0x7F);
        assert!(qb.symbols[1..].iter().all(|&s| s == 0));
    }

    #[test]
    fn grid_fixpoint() {
        // Quantize → dequantize → quantize is the identity on symbols.
        let mut rng = Rng::new(42);
        let mut data = vec![0f32; 64 * BLOCK];
        rng.fill_normal_f32(&mut data, 0.0, 1.0);
        let quant = q();
        let q1 = quant.quantize(&data);
        let deq = quant.dequantize(&q1);
        let q2 = quant.quantize(&deq);
        assert_eq!(q1.symbols, q2.symbols);
    }

    #[test]
    fn roundtrip_error_bound() {
        let mut rng = Rng::new(7);
        let mut data = vec![0f32; 256 * BLOCK];
        rng.fill_normal_f32(&mut data, 0.0, 2.0);
        let quant = q();
        let qb = quant.quantize(&data);
        let deq = quant.dequantize(&qb);
        for (b, chunk) in data.chunks_exact(BLOCK).enumerate() {
            let scale = qb.scales[b];
            for (i, (&x, &y)) in
                chunk.iter().zip(&deq[b * BLOCK..]).enumerate()
            {
                let err = (x - y).abs();
                let tol =
                    (x.abs() * 2.0f32.powi(-4)).max(scale * 2.0f32.powi(-10) * 1.001);
                assert!(err <= tol, "block {b} elem {i}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn multi_block_independent_scales() {
        let mut data = vec![0f32; 2 * BLOCK];
        data[..BLOCK].iter_mut().for_each(|x| *x = 1.0);
        data[BLOCK..].iter_mut().for_each(|x| *x = 100.0);
        let qb = q().quantize(&data);
        // Every element is its block's absmax → top code everywhere,
        // different scales.
        assert!(qb.symbols.iter().all(|&s| s == 0x7F));
        assert!(qb.scales[0] != qb.scales[1]);
    }

    #[test]
    #[should_panic(expected = "not a multiple")]
    fn rejects_partial_block() {
        q().quantize(&[1.0; 33]);
    }

    #[test]
    fn ocp_variant_never_emits_nan() {
        let quant = BlockQuantizer::new(Variant::Ocp);
        let mut rng = Rng::new(9);
        let mut data = vec![0f32; 128 * BLOCK];
        rng.fill_normal_f32(&mut data, 0.0, 10.0);
        let qb = quant.quantize(&data);
        assert!(qb.symbols.iter().all(|&s| (s & 0x7F) != 0x7F));
    }

    #[test]
    fn negative_zero_sign_preserved() {
        let mut data = [1.0f32; BLOCK];
        data[3] = -1e-12; // flushes to -0 symbol
        let qb = q().quantize(&data);
        assert_eq!(qb.symbols[3], SIGN_BIT);
        let deq = q().dequantize(&qb);
        assert_eq!(deq[3], 0.0);
    }

    #[test]
    fn prop_symbols_valid_and_error_bounded() {
        prop::check("quantizer invariants", Default::default(), |rng, size| {
            let blocks = 1 + rng.below((size / BLOCK + 1) as u64) as usize;
            let mut data = vec![0f32; blocks * BLOCK];
            let scale = 2.0f64.powi(rng.below(60) as i32 - 30);
            for v in data.iter_mut() {
                *v = (rng.normal() * scale) as f32;
            }
            let quant = q();
            let qb = quant.quantize(&data);
            if qb.scales.len() != blocks {
                return Err("scale count".into());
            }
            // Per-block: absmax element must get the top magnitude code.
            for (b, chunk) in data.chunks_exact(BLOCK).enumerate() {
                let absmax =
                    chunk.iter().fold(0f32, |a, &x| a.max(x.abs()));
                if absmax == 0.0 {
                    continue;
                }
                let arg = chunk
                    .iter()
                    .position(|&x| x.abs() == absmax)
                    .unwrap();
                let code = qb.symbols[b * BLOCK + arg] & 0x7F;
                if code != 0x7F {
                    return Err(format!(
                        "block {b}: absmax code {code:#x} != 0x7f"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn matches_python_golden_vector() {
        // Golden vector generated by python/compile/kernels/ref.py with
        // seed-free, hand-written inputs.  Guards cross-language drift.
        let data: Vec<f32> = (0..BLOCK)
            .map(|i| ((i as f32) - 15.5) / 4.0)
            .collect();
        let qb = q().quantize(&data);
        // absmax = 3.875; scale = 3.875/480.
        assert_eq!(qb.scales[0], 3.875f32 * (1.0 / 480.0));
        // Full 32-symbol pin, mirrored in
        // python/tests/test_cross_language.py::GOLDEN_SYMBOLS.
        const GOLDEN: [u8; 32] = [
            255, 254, 253, 252, 251, 250, 249, 248, 247, 245, 243, 241,
            238, 234, 228, 215, 87, 100, 106, 110, 113, 115, 117, 119,
            120, 121, 122, 123, 124, 125, 126, 127,
        ];
        assert_eq!(qb.symbols, GOLDEN);
        // Element 0 (-3.875) is the absmax → negative top code.
        assert_eq!(qb.symbols[0], 0xFF);
        // Element 31 (+3.875)... also absmax magnitude.
        assert_eq!(qb.symbols[31], 0x7F);
        // Element 15 = -0.125 → mag 0.125/3.875*480 = 15.48...
        // nearest e4m3 to 15.48 is 15 (idx: e=10... ) — just assert the
        // dequantized value is within one step.
        let deq = q().dequantize(&qb);
        assert!((deq[15] - data[15]).abs() < 0.125f32 * 0.07 + 1e-3);
    }
}
