//! PJRT runtime: loads the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built once by `make artifacts`) and executes
//! them from Rust.  Python is never on this path.
//!
//! Two executables:
//! * `ffn_step` — one fwd+bwd step of the L2 GeGLU FFN; returns the
//!   eight harvested tensor types as (e4m3 symbols, block scales),
//!   quantized on-device by the L1 Pallas kernel;
//! * `quantize` — the standalone block quantizer for arbitrary
//!   `(8192, 32)` f32 data.
//!
//! Pattern follows /opt/xla-example/load_hlo: HLO **text** →
//! `HloModuleProto::from_text_file` → compile on the CPU PJRT client →
//! execute (`return_tuple=True` on the JAX side, so outputs unpack with
//! `to_tuple`).

pub mod inputs;

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// One harvested tensor: e4m3 symbols + per-block scales.
#[derive(Clone, Debug)]
pub struct HarvestedTensor {
    pub name: String,
    pub symbols: Vec<u8>,
    pub scales: Vec<f32>,
}

struct TensorSpec {
    name: String,
    symbols_len: usize,
    scales_len: usize,
}

/// Loaded artifacts bound to a PJRT CPU client.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    ffn: xla::PjRtLoadedExecutable,
    quantize: xla::PjRtLoadedExecutable,
    input_shapes: Vec<(String, Vec<usize>)>,
    outputs: Vec<TensorSpec>,
    quant_blocks: usize,
}

impl Runtime {
    /// Load `manifest.json` + both HLO artifacts from `artifacts_dir`.
    pub fn load(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest_path = artifacts_dir.join("manifest.json");
        let manifest_text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let manifest = Json::parse(&manifest_text)
            .map_err(|e| anyhow!("manifest.json: {e}"))?;

        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;

        let ffn_json = manifest
            .get("ffn_step")
            .ok_or_else(|| anyhow!("manifest missing ffn_step"))?;
        let ffn = compile(
            &client,
            &artifacts_dir.join(get_str(ffn_json, "hlo")?),
        )?;
        let quant_json = manifest
            .get("quantize")
            .ok_or_else(|| anyhow!("manifest missing quantize"))?;
        let quantize = compile(
            &client,
            &artifacts_dir.join(get_str(quant_json, "hlo")?),
        )?;

        let mut input_shapes = Vec::new();
        for inp in ffn_json
            .get("inputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("ffn_step.inputs"))?
        {
            let name = get_str(inp, "name")?.to_string();
            let shape: Vec<usize> = inp
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("input shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            input_shapes.push((name, shape));
        }

        let mut outputs = Vec::new();
        for out in ffn_json
            .get("outputs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("ffn_step.outputs"))?
        {
            let sym_shape: Vec<usize> = out
                .get("symbols_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("symbols_shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            let scale_shape: Vec<usize> = out
                .get("scales_shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("scales_shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            outputs.push(TensorSpec {
                name: get_str(out, "name")?.to_string(),
                symbols_len: sym_shape.iter().product(),
                scales_len: scale_shape.iter().product(),
            });
        }

        let quant_blocks = quant_json
            .get("inputs")
            .and_then(|i| i.idx(0))
            .and_then(|i| i.get("shape"))
            .and_then(|s| s.idx(0))
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("quantize input shape"))?;

        Ok(Runtime {
            client,
            ffn,
            quantize,
            input_shapes,
            outputs,
            quant_blocks,
        })
    }

    /// Default artifacts directory (repo-root `artifacts/`).
    pub fn default_dir() -> PathBuf {
        PathBuf::from("artifacts")
    }

    /// Shapes of the five `ffn_step` inputs, in order.
    pub fn input_shapes(&self) -> &[(String, Vec<usize>)] {
        &self.input_shapes
    }

    pub fn tensor_names(&self) -> Vec<&str> {
        self.outputs.iter().map(|o| o.name.as_str()).collect()
    }

    pub fn quant_blocks(&self) -> usize {
        self.quant_blocks
    }

    /// Execute one FFN step on the given f32 inputs (flattened,
    /// matching [`Runtime::input_shapes`]).
    pub fn harvest_step(
        &self,
        inputs: &[Vec<f32>],
    ) -> Result<Vec<HarvestedTensor>> {
        if inputs.len() != self.input_shapes.len() {
            bail!(
                "expected {} inputs, got {}",
                self.input_shapes.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, (name, shape)) in inputs.iter().zip(&self.input_shapes) {
            let n: usize = shape.iter().product();
            if data.len() != n {
                bail!("input {name}: {} values for shape {shape:?}", data.len());
            }
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            literals.push(
                xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape {name}: {e:?}"))?,
            );
        }
        let result = self
            .ffn
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("ffn_step execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let parts = result
            .to_tuple()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        if parts.len() != self.outputs.len() * 2 {
            bail!(
                "ffn_step returned {} outputs, manifest says {}",
                parts.len(),
                self.outputs.len() * 2
            );
        }
        let mut harvested = Vec::with_capacity(self.outputs.len());
        for (i, spec) in self.outputs.iter().enumerate() {
            let symbols: Vec<u8> = parts[2 * i]
                .to_vec()
                .map_err(|e| anyhow!("{} symbols: {e:?}", spec.name))?;
            let scales: Vec<f32> = parts[2 * i + 1]
                .to_vec()
                .map_err(|e| anyhow!("{} scales: {e:?}", spec.name))?;
            if symbols.len() != spec.symbols_len
                || scales.len() != spec.scales_len
            {
                bail!(
                    "{}: got {}/{} values, manifest says {}/{}",
                    spec.name,
                    symbols.len(),
                    scales.len(),
                    spec.symbols_len,
                    spec.scales_len
                );
            }
            harvested.push(HarvestedTensor {
                name: spec.name.clone(),
                symbols,
                scales,
            });
        }
        Ok(harvested)
    }

    /// Quantize `(quant_blocks × 32)` f32 values through the AOT Pallas
    /// kernel. Returns (symbols, scales).
    pub fn quantize_blocks(&self, data: &[f32]) -> Result<(Vec<u8>, Vec<f32>)> {
        let n = self.quant_blocks * 32;
        if data.len() != n {
            bail!("quantize expects {n} values, got {}", data.len());
        }
        let lit = xla::Literal::vec1(data)
            .reshape(&[self.quant_blocks as i64, 32])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = self
            .quantize
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("quantize execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        let (syms, scales) = result
            .to_tuple2()
            .map_err(|e| anyhow!("untuple: {e:?}"))?;
        Ok((
            syms.to_vec().map_err(|e| anyhow!("symbols: {e:?}"))?,
            scales.to_vec().map_err(|e| anyhow!("scales: {e:?}"))?,
        ))
    }
}

fn compile(
    client: &xla::PjRtClient,
    path: &Path,
) -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
    )
    .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))
}

fn get_str<'a>(v: &'a Json, key: &str) -> Result<&'a str> {
    v.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("manifest missing string field '{key}'"))
}
