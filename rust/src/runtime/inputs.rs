//! Input synthesis for the `ffn_step` artifact: realistic trained-LLM
//! statistics matching `python/tests/test_model.py::_make_inputs`
//! (heavy-tailed tokens, gate-projection gain ≈ 2.5 so the bf16 GELU
//! saturates and FFN2 activations show the paper's zero spike).

use crate::util::rng::Rng;

/// Statistics knobs for one step's inputs.
#[derive(Clone, Copy, Debug)]
pub struct InputStats {
    /// Lognormal σ of the per-token scale of `x`.
    pub token_sigma: f64,
    /// Gain of the gate projection `wg`.
    pub gate_gain: f64,
}

impl Default for InputStats {
    fn default() -> Self {
        InputStats { token_sigma: 0.5, gate_gain: 2.5 }
    }
}

/// Build the five `ffn_step` inputs (x, wg, wu, w2, dy), flattened in
/// manifest order, given the shapes reported by the runtime.
pub fn make_step_inputs(
    shapes: &[(String, Vec<usize>)],
    stats: InputStats,
    rng: &mut Rng,
) -> Vec<Vec<f32>> {
    shapes
        .iter()
        .map(|(name, shape)| {
            let n: usize = shape.iter().product();
            let mut out = vec![0f32; n];
            match name.as_str() {
                "x" => {
                    // Heavy-tailed tokens: per-row lognormal scale.
                    let cols = *shape.last().unwrap();
                    for row in out.chunks_mut(cols) {
                        let s = rng.lognormal(0.0, stats.token_sigma);
                        for v in row.iter_mut() {
                            *v = (rng.normal() * s) as f32;
                        }
                    }
                }
                "wg" => {
                    let fan_in = shape[0] as f64;
                    let std = stats.gate_gain / fan_in.sqrt();
                    rng.fill_normal_f32(&mut out, 0.0, std as f32);
                }
                "wu" | "w2" => {
                    let fan_in = shape[0] as f64;
                    let std = 1.0 / fan_in.sqrt();
                    rng.fill_normal_f32(&mut out, 0.0, std as f32);
                }
                "dy" => {
                    rng.fill_normal_f32(&mut out, 0.0, 1.0);
                }
                other => panic!("unknown ffn_step input '{other}'"),
            }
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shapes() -> Vec<(String, Vec<usize>)> {
        vec![
            ("x".into(), vec![64, 32]),
            ("wg".into(), vec![32, 128]),
            ("wu".into(), vec![32, 128]),
            ("w2".into(), vec![128, 32]),
            ("dy".into(), vec![64, 32]),
        ]
    }

    #[test]
    fn shapes_respected() {
        let mut rng = Rng::new(1);
        let inputs =
            make_step_inputs(&shapes(), InputStats::default(), &mut rng);
        assert_eq!(inputs.len(), 5);
        assert_eq!(inputs[0].len(), 64 * 32);
        assert_eq!(inputs[1].len(), 32 * 128);
    }

    #[test]
    fn gate_gain_scales_wg() {
        let mut rng = Rng::new(2);
        let hi = make_step_inputs(
            &shapes(),
            InputStats { gate_gain: 5.0, ..Default::default() },
            &mut rng,
        );
        let mut rng = Rng::new(2);
        let lo = make_step_inputs(
            &shapes(),
            InputStats { gate_gain: 1.0, ..Default::default() },
            &mut rng,
        );
        let var = |v: &[f32]| {
            v.iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / v.len() as f64
        };
        assert!(var(&hi[1]) > 10.0 * var(&lo[1]));
        // wu unaffected by gate gain.
        assert!((var(&hi[2]) / var(&lo[2]) - 1.0).abs() < 0.2);
    }

    #[test]
    fn deterministic() {
        let a = make_step_inputs(
            &shapes(),
            InputStats::default(),
            &mut Rng::new(7),
        );
        let b = make_step_inputs(
            &shapes(),
            InputStats::default(),
            &mut Rng::new(7),
        );
        assert_eq!(a, b);
    }

    #[test]
    fn token_rows_have_varying_scale() {
        let mut rng = Rng::new(3);
        let inputs = make_step_inputs(
            &shapes(),
            InputStats { token_sigma: 1.0, ..Default::default() },
            &mut rng,
        );
        let x = &inputs[0];
        let row_norm = |r: usize| {
            x[r * 32..(r + 1) * 32]
                .iter()
                .map(|&v| (v as f64).powi(2))
                .sum::<f64>()
                .sqrt()
        };
        let norms: Vec<f64> = (0..64).map(row_norm).collect();
        let max = norms.iter().cloned().fold(0.0, f64::max);
        let min = norms.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min > 2.0, "token scales should vary: {min}..{max}");
    }
}
