//! Paper-artifact regeneration: one function per figure/table of the
//! evaluation (DESIGN.md §5 experiment index).  Shared by the `qlc
//! tables` CLI subcommand and `benches/paper_tables.rs`; every function
//! returns both a human-readable text block and a JSON object so
//! EXPERIMENTS.md entries are reproducible verbatim.

use crate::codecs::elias::{EliasCodec, EliasKind};
use crate::codecs::expgolomb::ExpGolombCodec;
use crate::codecs::huffman::HuffmanCodec;
use crate::codecs::qlc::{optimizer, AreaScheme, QlcCodec};
use crate::codecs::Codec;
use crate::data::shards::{ShardConfig, ShardSet};
use crate::data::{calibrate_generator, TensorKind};
use crate::stats::{Histogram, Pmf};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The two PMF families the paper evaluates, produced by calibrated
/// generators over the paper's shard topology.
pub struct PaperPmfs {
    /// FFN1-activation-like (smooth; paper entropy 6.69 bits).
    pub ffn1: Pmf,
    /// FFN2-activation-like (zero-spiked; paper entropy 6.11 bits).
    pub ffn2: Pmf,
    /// Pooled histograms (for Huffman builds).
    pub ffn1_hist: Histogram,
    pub ffn2_hist: Histogram,
}

/// Build both PMFs: calibrate the generators to the paper's entropies,
/// then average over a scaled-down version of the paper's 18×64 shard
/// grid.  `scale=6` (3 layers × 10 shards) keeps this under a second;
/// the benches use `scale=1` for the full grid.
pub fn paper_pmfs(seed: u64, scale: usize) -> PaperPmfs {
    let (g1, _) = calibrate_generator(TensorKind::Ffn1Act, 6.69, seed, 0.02);
    let (g2, _) = calibrate_generator(TensorKind::Ffn2Act, 6.11, seed, 0.02);
    let config = ShardConfig::paper_scaled(scale);
    let s1 = ShardSet::generate(TensorKind::Ffn1Act, config, g1.knob, seed);
    let s2 =
        ShardSet::generate(TensorKind::Ffn2Act, config, g2.knob, seed ^ 0xFF);
    PaperPmfs {
        ffn1: s1.average_pmf(),
        ffn2: s2.average_pmf(),
        ffn1_hist: s1.pooled(),
        ffn2_hist: s2.pooled(),
    }
}

/// Sample symbols from a PMF (for decode benches / hw simulation).
pub fn sample_symbols(pmf: &Pmf, n: usize, seed: u64) -> Vec<u8> {
    let table = crate::util::rng::AliasTable::new(&pmf.p);
    let mut rng = Rng::new(seed);
    table.sample_many(&mut rng, n)
}

/// One rendered artifact.
pub struct Artifact {
    pub id: String,
    pub text: String,
    pub json: Json,
}

/// An observability snapshot as a report artifact: the text form is
/// the Prometheus exposition, the JSON form is the snapshot itself
/// (parseable back via [`Snapshot::parse`](crate::obs::Snapshot)).
/// Used by `qlc collective --metrics`.
pub fn obs_artifact(id: &str, snap: &crate::obs::Snapshot) -> Artifact {
    Artifact {
        id: id.to_string(),
        text: snap.to_prometheus(),
        json: snap.to_json(),
    }
}

fn hist_from_pmf(pmf: &Pmf) -> Histogram {
    // Huffman construction needs counts; scale probabilities to a large
    // virtual sample (the paper's shards hold ~1.15e9 symbols/type).
    let mut h = Histogram::new();
    for i in 0..256 {
        h.counts[i] = (pmf.p[i] * 1.15e9) as u64;
    }
    h
}

/// Figs 1 & 4: sorted PMF + entropy + ideal compressibility.
pub fn fig_sorted_pmf(id: &str, label: &str, pmf: &Pmf) -> Artifact {
    let sorted = pmf.sorted_desc();
    let h = pmf.entropy();
    let ideal = pmf.ideal_compressibility();
    let mut text = format!(
        "{id}: sorted PMF of {label}\n  entropy = {h:.2} bits, ideal \
         compressibility = {:.1}%\n  top probabilities: ",
        ideal * 100.0
    );
    for p in sorted.iter().take(8) {
        text += &format!("{p:.4} ");
    }
    text += &format!("... p[255] = {:.2e}\n", sorted[255]);
    let json = Json::obj()
        .set("id", id)
        .set("label", label)
        .set("entropy_bits", h)
        .set("ideal_compressibility", ideal)
        .set("sorted_pmf", sorted.to_vec());
    Artifact { id: id.into(), text, json }
}

/// Figs 2 & 5: Huffman code lengths by rank.
pub fn fig_huffman_lengths(id: &str, label: &str, pmf: &Pmf) -> Artifact {
    let codec = HuffmanCodec::from_histogram(&hist_from_pmf(pmf));
    let lengths = codec.code_lengths();
    let rank = pmf.rank_order();
    let by_rank: Vec<u32> =
        rank.iter().map(|&s| lengths[s as usize]).collect();
    let (min, max) = (codec.min_length(), codec.max_length());
    let comp = pmf.compressibility(&lengths);
    let text = format!(
        "{id}: Huffman code lengths for {label}\n  lengths range {min}–{max} \
         bits (paper FFN1: 6–18, FFN2: 3–39)\n  compressibility = {:.1}%\n  \
         rank 0 → {} bits, rank 128 → {} bits, rank 255 → {} bits\n",
        comp * 100.0,
        by_rank[0],
        by_rank[128],
        by_rank[255]
    );
    let json = Json::obj()
        .set("id", id)
        .set("label", label)
        .set("min_length", min as usize)
        .set("max_length", max as usize)
        .set("compressibility", comp)
        .set(
            "lengths_by_rank",
            by_rank.iter().map(|&l| l as usize).collect::<Vec<_>>(),
        );
    Artifact { id: id.into(), text, json }
}

/// Tables 1 & 2: the scheme itself plus measured compressibility.
pub fn table_scheme(
    id: &str,
    label: &str,
    scheme: &AreaScheme,
    pmf: &Pmf,
) -> Artifact {
    let sorted = pmf.sorted_desc();
    let huffman = HuffmanCodec::from_histogram(&hist_from_pmf(pmf));
    let qlc_comp = scheme.compressibility_sorted(&sorted);
    let huff_comp = pmf.compressibility(&huffman.code_lengths());
    let mut text = format!(
        "{id}: quad length coding scheme on {label}\n  Area | code | #sym | \
         sym bits | code len | range\n"
    );
    let mut rows = Vec::new();
    for (i, a) in scheme.areas.iter().enumerate() {
        let base = scheme.base_rank(i);
        text += &format!(
            "  {:>4} | {:0width$b} | {:>4} | {:>8} | {:>8} | {}-{}\n",
            i + 1,
            i,
            a.size,
            a.symbol_bits,
            scheme.code_length(i),
            base,
            base + a.size as u32 - 1,
            width = scheme.prefix_bits as usize
        );
        rows.push(
            Json::obj()
                .set("area", i + 1)
                .set("symbols", a.size as usize)
                .set("symbol_bits", a.symbol_bits as usize)
                .set("code_length", scheme.code_length(i) as usize)
                .set("base_rank", base as usize),
        );
    }
    text += &format!(
        "  compressibility: QLC = {:.1}%  vs Huffman = {:.1}%  (paper T1: \
         13.9% vs 15.9%, T2: 19.0% vs 23.2%)\n",
        qlc_comp * 100.0,
        huff_comp * 100.0
    );
    let json = Json::obj()
        .set("id", id)
        .set("label", label)
        .set("prefix_bits", scheme.prefix_bits as usize)
        .set("areas", Json::Arr(rows))
        .set("qlc_compressibility", qlc_comp)
        .set("huffman_compressibility", huff_comp);
    Artifact { id: id.into(), text, json }
}

/// Figs 3 & 6: code length by rank, Huffman vs QLC.
pub fn fig_length_compare(
    id: &str,
    label: &str,
    scheme: &AreaScheme,
    pmf: &Pmf,
) -> Artifact {
    let huffman = HuffmanCodec::from_histogram(&hist_from_pmf(pmf));
    let hlen = huffman.code_lengths();
    let rank = pmf.rank_order();
    let h_by_rank: Vec<u32> = rank.iter().map(|&s| hlen[s as usize]).collect();
    let q_by_rank = scheme.rank_lengths();
    let mut text = format!(
        "{id}: code lengths, Huffman vs QLC, for {label}\n  rank: huffman \
         qlc\n"
    );
    for &r in &[0usize, 8, 32, 40, 56, 88, 128, 192, 255] {
        text += &format!(
            "  {:>4}: {:>7} {:>4}\n",
            r, h_by_rank[r], q_by_rank[r]
        );
    }
    let json = Json::obj()
        .set("id", id)
        .set("label", label)
        .set(
            "huffman_by_rank",
            h_by_rank.iter().map(|&l| l as usize).collect::<Vec<_>>(),
        )
        .set(
            "qlc_by_rank",
            q_by_rank.iter().map(|&l| l as usize).collect::<Vec<_>>(),
        );
    Artifact { id: id.into(), text, json }
}

/// Fig 7: symbol-indexed (unsorted) PMF with modal symbols.
pub fn fig_symbol_pmf(id: &str, label: &str, pmf: &Pmf) -> Artifact {
    let rank = pmf.rank_order();
    let top: Vec<usize> = rank[..4].iter().map(|&s| s as usize).collect();
    let bottom: Vec<usize> =
        rank[252..].iter().map(|&s| s as usize).collect();
    let text = format!(
        "{id}: symbol-indexed PMF of {label}\n  most frequent symbols: \
         {top:?} (paper: [113, 241, 234, 106])\n  least frequent symbols: \
         {bottom:?} (paper: [.., 141, 137, 0, 128])\n",
    );
    let json = Json::obj()
        .set("id", id)
        .set("label", label)
        .set("pmf", pmf.p.to_vec())
        .set("top_symbols", top)
        .set("bottom_symbols", bottom);
    Artifact { id: id.into(), text, json }
}

/// Tables 3 & 4: encoder/decoder LUT excerpts.
pub fn table_luts(id: &str, pmf: &Pmf, scheme: AreaScheme) -> Artifact {
    let codec = QlcCodec::from_pmf(scheme, pmf);
    // Paper Table 3 shows rows for mapped ranks 0,1,2,8,253,254,255.
    let mut text = format!(
        "{id}: encoder LUT (input → rank → code) and decoder LUT excerpts\n"
    );
    let by_rank = codec.rank_order();
    for &r in &[0usize, 1, 2, 8, 253, 254, 255] {
        let sym = by_rank[r];
        let (_, rank, code, len) = codec.encoder_row(sym);
        text += &format!(
            "  enc: input {sym:>3} → rank {rank:>3} → {:0width$b} ({len} \
             bits)   dec: {r:>3} → {}\n",
            code,
            codec.decoder_row(r as u8).1,
            width = len as usize
        );
    }
    let json = Json::obj().set("id", id).set(
        "encoder_rows",
        Json::Arr(
            codec
                .encoder_table()
                .map(|(s, r, c, l)| {
                    Json::obj()
                        .set("input", s as usize)
                        .set("rank", r as usize)
                        .set("code", c as usize)
                        .set("bits", l as usize)
                })
                .collect(),
        ),
    );
    Artifact { id: id.into(), text, json }
}

/// The codec-comparison summary (headline + baselines) for one PMF.
pub fn codec_comparison(id: &str, label: &str, pmf: &Pmf) -> Artifact {
    let hist = hist_from_pmf(pmf);
    let rank = pmf.rank_order();
    let sorted = pmf.sorted_desc();
    let mut rows: Vec<(String, f64)> = Vec::new();
    rows.push(("ideal (entropy)".into(), pmf.ideal_compressibility()));
    let huff = HuffmanCodec::from_histogram(&hist);
    rows.push(("huffman".into(), pmf.compressibility(&huff.code_lengths())));
    for (name, scheme) in [
        ("qlc-t1", AreaScheme::table1()),
        ("qlc-t2", AreaScheme::table2()),
    ] {
        rows.push((name.into(), scheme.compressibility_sorted(&sorted)));
    }
    let opt = optimizer::optimize_scheme(&sorted);
    rows.push((
        format!("qlc-opt (p={})", opt.prefix_bits),
        opt.compressibility_sorted(&sorted),
    ));
    for kind in [EliasKind::Gamma, EliasKind::Delta, EliasKind::Omega] {
        let ranked = EliasCodec::with_ranking(kind, &rank);
        rows.push((
            format!("{}-ranked", kind.name()),
            pmf.compressibility(&ranked.code_lengths()),
        ));
    }
    for k in [2u32, 4] {
        let eg = ExpGolombCodec::with_ranking(k, &rank);
        rows.push((
            format!("eg{k}-ranked"),
            pmf.compressibility(&eg.code_lengths()),
        ));
    }
    let mut text = format!("{id}: compressibility by codec on {label}\n");
    for (name, c) in &rows {
        text += &format!("  {name:<22} {:>6.1}%\n", c * 100.0);
    }
    let json = Json::obj().set("id", id).set("label", label).set(
        "rows",
        Json::Arr(
            rows.iter()
                .map(|(n, c)| {
                    Json::obj()
                        .set("codec", n.as_str())
                        .set("compressibility", *c)
                })
                .collect(),
        ),
    );
    Artifact { id: id.into(), text, json }
}

/// Every paper artifact in order (the `--all` path and the bench).
pub fn all_artifacts(pmfs: &PaperPmfs) -> Vec<Artifact> {
    vec![
        fig_sorted_pmf("FIG1", "FFN1 activation", &pmfs.ffn1),
        fig_huffman_lengths("FIG2", "FFN1 activation", &pmfs.ffn1),
        table_scheme("TAB1", "FFN1 activation", &AreaScheme::table1(), &pmfs.ffn1),
        fig_length_compare(
            "FIG3",
            "FFN1 activation",
            &AreaScheme::table1(),
            &pmfs.ffn1,
        ),
        fig_sorted_pmf("FIG4", "FFN2 activation", &pmfs.ffn2),
        fig_huffman_lengths("FIG5", "FFN2 activation", &pmfs.ffn2),
        table_scheme("TAB2", "FFN2 activation", &AreaScheme::table2(), &pmfs.ffn2),
        fig_length_compare(
            "FIG6",
            "FFN2 activation",
            &AreaScheme::table2(),
            &pmfs.ffn2,
        ),
        fig_symbol_pmf("FIG7", "FFN1 activation", &pmfs.ffn1),
        table_luts("TAB3+4", &pmfs.ffn1, AreaScheme::table1()),
        codec_comparison("SUMMARY-FFN1", "FFN1 activation", &pmfs.ffn1),
        codec_comparison("SUMMARY-FFN2", "FFN2 activation", &pmfs.ffn2),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pmfs() -> PaperPmfs {
        paper_pmfs(42, 12) // small grid for test speed
    }

    #[test]
    fn calibrated_entropies_near_paper() {
        let p = pmfs();
        let h1 = p.ffn1.entropy();
        let h2 = p.ffn2.entropy();
        assert!((h1 - 6.69).abs() < 0.25, "FFN1 entropy {h1}");
        assert!((h2 - 6.11).abs() < 0.30, "FFN2 entropy {h2}");
    }

    #[test]
    fn headline_ordering_holds() {
        // The paper's qualitative result: ideal > Huffman > QLC on both
        // PMFs, with QLC within a few points of Huffman.
        let p = pmfs();
        for (pmf, scheme) in [
            (&p.ffn1, AreaScheme::table1()),
            (&p.ffn2, AreaScheme::table2()),
        ] {
            let sorted = pmf.sorted_desc();
            let hist = hist_from_pmf(pmf);
            let huff = HuffmanCodec::from_histogram(&hist);
            let ideal = pmf.ideal_compressibility();
            let h = pmf.compressibility(&huff.code_lengths());
            let q = scheme.compressibility_sorted(&sorted);
            assert!(ideal >= h - 1e-9, "{ideal} vs {h}");
            assert!(h > q, "huffman {h} must beat qlc {q}");
            assert!(h - q < 0.06, "gap {h}-{q} too wide");
        }
    }

    #[test]
    fn t2_beats_t1_on_ffn2() {
        // Paper §6: adapting the scheme recovers ~2.3 points on FFN2.
        let p = pmfs();
        let sorted = p.ffn2.sorted_desc();
        let t1 = AreaScheme::table1().compressibility_sorted(&sorted);
        let t2 = AreaScheme::table2().compressibility_sorted(&sorted);
        assert!(t2 > t1, "t2 {t2} must beat t1 {t1} on the spiked PMF");
    }

    #[test]
    fn t1_beats_t2_on_ffn1() {
        let p = pmfs();
        let sorted = p.ffn1.sorted_desc();
        let t1 = AreaScheme::table1().compressibility_sorted(&sorted);
        let t2 = AreaScheme::table2().compressibility_sorted(&sorted);
        assert!(t1 > t2, "t1 {t1} must beat t2 {t2} on the smooth PMF");
    }

    #[test]
    fn optimizer_at_least_matches_hand_schemes() {
        let p = pmfs();
        for (pmf, hand) in [
            (&p.ffn1, AreaScheme::table1()),
            (&p.ffn2, AreaScheme::table2()),
        ] {
            let sorted = pmf.sorted_desc();
            let opt = optimizer::optimize_scheme(&sorted);
            assert!(
                opt.compressibility_sorted(&sorted)
                    >= hand.compressibility_sorted(&sorted) - 1e-9
            );
        }
    }

    #[test]
    fn all_artifacts_render() {
        let p = pmfs();
        let arts = all_artifacts(&p);
        assert_eq!(arts.len(), 12);
        for a in &arts {
            assert!(!a.text.is_empty(), "{}", a.id);
            // JSON must be serializable + re-parseable.
            let text = a.json.to_string_pretty();
            assert!(Json::parse(&text).is_ok(), "{}", a.id);
        }
    }

    #[test]
    fn huffman_range_wider_on_spiked_pmf() {
        // Paper: FFN1 lengths 6–18; FFN2 lengths 3–39 (deeper tree).
        let p = pmfs();
        let h1 = HuffmanCodec::from_histogram(&hist_from_pmf(&p.ffn1));
        let h2 = HuffmanCodec::from_histogram(&hist_from_pmf(&p.ffn2));
        assert!(h2.min_length() < h1.min_length());
        assert!(h2.max_length() >= h1.max_length());
    }

    #[test]
    fn sample_symbols_match_pmf() {
        let p = pmfs();
        let symbols = sample_symbols(&p.ffn1, 200_000, 1);
        let measured = Histogram::from_symbols(&symbols).pmf();
        assert!(measured.tv_distance(&p.ffn1) < 0.02);
    }
}
