//! `qlc` — command-line entry point for the Quad Length Codes stack.
//!
//! Subcommands:
//!   tables      regenerate the paper's figures/tables (DESIGN.md §5)
//!   analyze     static-analysis linter over the crate's own source
//!   entropy     PMF/entropy/codec comparison for generated or trace data
//!   compress    compress a raw symbol file into a self-describing frame
//!   decompress  invert `compress`
//!   datagen     write calibrated symbol traces to a directory
//!   optimize    run the area-scheme optimizer on a tensor kind
//!   collective  compressed ring collectives on the simulated fabric
//!   hw          decoder hardware-model comparison
//!   harvest     execute the AOT FFN artifact via PJRT and save traces
//!   pipeline    run the leader/worker compression pipeline demo
//!   serve       event-driven streaming compression server (epoll)
//!   call        one compress/decompress round trip against a server
//!   loadgen     M concurrent verified round-trip streams + latency
//!   worker      one rank of a multi-process TCP ring collective
//!   launch      spawn N local worker processes over 127.0.0.1
//!
//! Run `qlc help` for options.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use qlc::codecs::frame::{self, FrameOptions, ShardManifest};
use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::CodecRegistry;
use qlc::codecs::qlc::{optimizer, QlcCodec};
use qlc::collective::{self, Fabric, Transport};
use qlc::coordinator::{Pipeline, PipelineConfig};
use qlc::data::trace::Trace;
use qlc::data::{calibrate_generator, TensorGen, TensorKind};
use qlc::formats::Variant;
use qlc::hw;
use qlc::obs;
use qlc::report;
#[cfg(feature = "pjrt")]
use qlc::runtime::{inputs::InputStats, Runtime};
use qlc::stats::Histogram;
use qlc::util::cli::{self, Args};
use qlc::util::json::Json;
use qlc::util::rng::Rng;

const VALUE_OPTS: &[&str] = &[
    "fig", "table", "codec", "kind", "n", "seed", "scale", "workers", "op",
    "size", "bandwidth-gbps", "latency-us", "fabric", "shards", "out",
    "artifacts", "steps", "chunk", "queue", "target-entropy", "knob", "dir",
    "name", "prefix", "rank", "world", "listen", "connect", "timeout-s",
    "decode", "encode", "src", "baseline", "explain", "trace", "metrics",
    "reactor", "max-requests", "max-conns", "streams", "requests",
];

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match cli::parse(&argv, VALUE_OPTS) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            return ExitCode::FAILURE;
        }
    };
    let result = match args.subcommand.as_deref() {
        Some("tables") => cmd_tables(&args),
        Some("analyze") => cmd_analyze(&args),
        Some("entropy") => cmd_entropy(&args),
        Some("compress") => cmd_compress(&args),
        Some("decompress") => cmd_decompress(&args),
        Some("datagen") => cmd_datagen(&args),
        Some("optimize") => cmd_optimize(&args),
        Some("collective") => cmd_collective(&args),
        Some("hw") => cmd_hw(&args),
        Some("formats") => cmd_formats(&args),
        Some("harvest") => cmd_harvest(&args),
        Some("pipeline") => cmd_pipeline(&args),
        Some("serve") => cmd_serve(&args),
        Some("call") => cmd_call(&args),
        Some("loadgen") => cmd_loadgen(&args),
        Some("worker") => cmd_worker(&args),
        Some("launch") => cmd_launch(&args),
        Some("help") | None => {
            print!("{}", HELP);
            Ok(())
        }
        Some(other) => Err(format!("unknown subcommand '{other}'; try help")),
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

const HELP: &str = "qlc — Quad Length Codes for lossless e4m3 compression

USAGE: qlc <subcommand> [options]

  tables     [--fig N | --table N | --all] [--seed S] [--scale K] [--json]
  analyze    [--src DIR] [--baseline FILE] [--update-baseline] [--deny-new]
             [--deny-stale] [--json] [--explain RULE|all]
             (dependency-free dataflow linter over the crate source:
              taint from wire reads to allocation/cast/index/loop
              sinks plus reactor lifecycle — unchecked-narrowing,
              cap-before-alloc, panic-free, safety-comment,
              forbidden-construct, tainted-loop-bound,
              tainted-length-arith, reactor-interest-leak; prints
              file:line: rule: message with the source-to-sink taint
              chain and exits non-zero on findings not grandfathered
              by the baseline.  Stale baseline entries warn by
              default and fail under --deny-stale; --json emits the
              machine-readable report; --explain RULE prints a
              rule's contract, waiver syntax, and worked example)
  entropy    [--kind ffn1_act|ffn2_act|weight|wgrad|agrad] [--n SYMBOLS]
             [--dir TRACES --name NAME] [--json]
  compress   <in> <out> --codec raw|huffman|qlc|qlc-t1|qlc-t2|elias-*|egK
             [--qlf1]   (legacy single-payload frame; default is
                         chunked QLF2, decoded in parallel)
             [--adaptive-chunks]  (QLF2 + qlc only: re-fit the rank
                         tables per chunk when the chunk's PMF drifts
                         past break-even; drifting streams compress
                         better, chunks stay independently decodable)
             [--shards N]  (QLM1 manifest at <out> + <out>.shardK files,
                            one table header shared by all shards)
             [--encode batched|scalar|lanes]
                          (which encode path writes the chunks: the
                           batched staging-word kernel, the scalar
                           reference path, or lane-interleaved encode
                           of independent chunks; every mode writes
                           bit-identical frames; default batched)
  decompress <in> <out> [--decode batched|scalar|lanes]
                          (reads QLF1, QLF2 and QLM1 manifests —
                           shard files are found next to the manifest;
                           --decode picks the batched kernel, the
                           scalar reference path, or lane-interleaved
                           multi-cursor decode of independent chunks;
                           default batched)
  datagen    --kind K --n SYMBOLS --out DIR [--seed S]
             [--target-entropy H | --knob X]
  optimize   [--kind K | --dir TRACES --name NAME] [--prefix P] [--json]
  collective --op allreduce|allgather --workers W --size N --codec C
             [--fabric pod|superpod|ethernet]
             [--bandwidth-gbps G] [--latency-us L] [--json]
             [--trace FILE]    (Chrome trace-event JSON of the run's
                                spans — load in Perfetto/about:tracing)
             [--metrics FILE]  (metric snapshot: Prometheus text, or
                                the JSON form when FILE ends in .json)
             (reports serial + chunk-pipelined time and overlap savings)
  hw         [--seed S] [--n SYMBOLS] [--json]
  formats    [--n SYMBOLS] [--seed S]      cross-eXmY-format QLC sweep
  harvest    [--artifacts DIR] --out DIR [--steps N] [--seed S]
             (needs a build with --features pjrt)
  pipeline   [--codec C] [--workers W] [--chunk BYTES] [--n SYMBOLS]
             [--shards N]  (emit a sharded manifest instead of frames)
  serve      [--listen ADDR] [--reactor auto|epoll|fallback]
             [--max-requests N] [--max-conns N]
             [--trace FILE] [--metrics FILE]
             (event-driven streaming compression server: clients
              handshake a codec per connection, then stream QWC1
              chunk frames; encoder/decoder sessions are reused
              across a connection's requests; a slow reader
              backpressures only its own stream; --max-requests N
              drains and exits after N requests — 0 runs forever)
  call       <in> <out> --connect ADDR [--op compress|decompress]
             [--codec C] [--chunk BYTES]
             [--reactor auto|epoll|fallback] [--timeout-s T]
             (one round trip: compress writes a self-describing
              container — the handshake plus the compressed response
              frames — and decompress replays such a container back
              into raw bytes)
  loadgen    (--connect ADDR | --bench) [--streams M] [--requests R]
             [--size BYTES] [--chunk BYTES] [--codec C]
             [--reactor auto|epoll|fallback] [--seed S]
             [--timeout-s T] [--verify] [--json] [--out FILE]
             (M concurrent streams, each running compress→decompress
              round trips and checking them bit-exactly; reports
              aggregate MB/s and per-op p50/p99 request latency;
              --bench spins an in-process server per reactor backend
              and writes the BENCH_9.json comparison)
  worker     --world N --rank R (--listen ADDR | --connect ADDR)
             [--op allreduce|allgather] [--codec C] [--size N]
             [--chunk SYMBOLS] [--seed S] [--timeout-s T]
             [--out FILE] [--json]
             [--trace FILE] [--metrics FILE]
             (rank 0 listens for the rendezvous; other ranks connect;
              the ring then runs over real TCP sockets; --trace writes
              this rank's Chrome trace with pid = rank, --metrics its
              metric snapshot — Prometheus text, or JSON when FILE
              ends in .json)
  launch     --world N [--op allreduce|allgather] [--codec C] [--size N]
             [--chunk SYMBOLS] [--seed S] [--timeout-s T] [--json]
             [--trace FILE] [--metrics FILE]
             (spawns N local `qlc worker` processes on 127.0.0.1 and
              checks all ranks finish with bit-identical results;
              --trace merges every rank's trace into one world-level
              Chrome trace — one pid per rank — and --metrics folds
              every rank's counters/histograms into one snapshot)
";

// ---------------------------------------------------------------------------

fn cmd_tables(args: &Args) -> Result<(), String> {
    let seed = args.opt_u64("seed", 42).map_err(|e| e.to_string())?;
    let scale = args.opt_usize("scale", 6).map_err(|e| e.to_string())?;
    let pmfs = report::paper_pmfs(seed, scale);
    let artifacts = report::all_artifacts(&pmfs);
    let want_fig = args.opt("fig");
    let want_table = args.opt("table");
    let all =
        args.has_flag("all") || (want_fig.is_none() && want_table.is_none());
    for a in &artifacts {
        let keep = all
            || want_fig.map(|f| a.id == format!("FIG{f}")).unwrap_or(false)
            || want_table
                .map(|t| a.id.contains(&format!("TAB{t}")))
                .unwrap_or(false);
        if keep {
            if args.has_flag("json") {
                println!("{}", a.json.to_string_pretty());
            } else {
                println!("{}", a.text);
            }
        }
    }
    Ok(())
}

fn load_symbols(args: &Args) -> Result<(String, Vec<u8>), String> {
    if let (Some(dir), Some(name)) = (args.opt("dir"), args.opt("name")) {
        let trace =
            Trace::load(Path::new(dir), name).map_err(|e| e.to_string())?;
        return Ok((name.to_string(), trace.symbols));
    }
    let kind_s = args.opt_or("kind", "ffn1_act");
    let kind =
        TensorKind::parse(&kind_s).ok_or(format!("bad kind {kind_s}"))?;
    let n = args.opt_usize("n", 1 << 20).map_err(|e| e.to_string())?;
    let seed = args.opt_u64("seed", 1).map_err(|e| e.to_string())?;
    let gen = TensorGen::new(kind, Variant::ExmY);
    let mut rng = Rng::new(seed);
    Ok((kind_s, gen.symbols(&mut rng, n - n % 32)))
}

fn cmd_analyze(args: &Args) -> Result<(), String> {
    use qlc::analysis::{self, baseline};
    if let Some(which) = args.opt("explain") {
        return explain_rules(which);
    }
    let src = match args.opt("src") {
        Some(dir) => PathBuf::from(dir),
        None => ["src", "rust/src"]
            .iter()
            .map(PathBuf::from)
            .find(|p| p.is_dir())
            .ok_or("cannot locate the crate source tree; pass --src DIR")?,
    };
    let baseline_path = match args.opt("baseline") {
        Some(p) => PathBuf::from(p),
        None => src
            .parent()
            .unwrap_or(Path::new("."))
            .join("analysis/baseline.txt"),
    };
    let findings = analysis::analyze_tree(&src)?;
    if args.has_flag("update-baseline") {
        if let Some(dir) = baseline_path.parent() {
            std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        }
        std::fs::write(&baseline_path, baseline::render(&findings))
            .map_err(|e| e.to_string())?;
        println!(
            "wrote {} finding(s) to {}",
            findings.len(),
            baseline_path.display()
        );
        return Ok(());
    }
    let known = match std::fs::read_to_string(&baseline_path) {
        Ok(text) => baseline::parse(&text),
        Err(_) => Default::default(),
    };
    let (fresh, grandfathered) = baseline::split(&findings, &known);
    let stale = baseline::stale(&findings, &known);
    if args.has_flag("json") {
        println!(
            "{}",
            analysis::json_report(&findings, &known).to_string_pretty()
        );
    } else {
        for f in &fresh {
            println!("{}", f.render());
        }
        println!(
            "qlc analyze: {} file finding(s), {} baselined, {} new",
            findings.len(),
            grandfathered.len(),
            fresh.len()
        );
    }
    for entry in &stale {
        eprintln!(
            "warning: stale baseline entry (no matching finding): {entry}"
        );
    }
    if args.has_flag("deny-stale") && !stale.is_empty() {
        return Err(format!(
            "{} stale baseline entr{}; prune them or regenerate with \
             --update-baseline",
            stale.len(),
            if stale.len() == 1 { "y" } else { "ies" }
        ));
    }
    if fresh.is_empty() {
        Ok(())
    } else {
        Err(format!(
            "{} new analysis finding(s); fix, waive, or re-baseline with \
             --update-baseline",
            fresh.len()
        ))
    }
}

/// `qlc analyze --explain <rule|all>`: print each rule's contract,
/// waiver syntax, and a worked example.
fn explain_rules(which: &str) -> Result<(), String> {
    use qlc::analysis::rules::RULES;
    let selected: Vec<_> = if which == "all" {
        RULES.iter().collect()
    } else {
        RULES.iter().filter(|r| r.name == which).collect()
    };
    if selected.is_empty() {
        return Err(format!(
            "unknown rule '{which}'; known rules: {}",
            RULES
                .iter()
                .map(|r| r.name)
                .collect::<Vec<_>>()
                .join(", ")
        ));
    }
    for (i, r) in selected.iter().enumerate() {
        if i > 0 {
            println!();
        }
        println!("{}", r.name);
        println!("  contract: {}", r.contract);
        println!("  waiver:   {}", r.waiver);
        println!("  example:  {}", r.example.replace('\n', "\n    "));
    }
    Ok(())
}

fn cmd_entropy(args: &Args) -> Result<(), String> {
    let (label, symbols) = load_symbols(args)?;
    let pmf = Histogram::from_symbols(&symbols).pmf();
    let art = report::codec_comparison("ANALYZE", &label, &pmf);
    if args.has_flag("json") {
        println!("{}", art.json.to_string_pretty());
    } else {
        println!(
            "{} symbols, entropy {:.3} bits\n{}",
            symbols.len(),
            pmf.entropy(),
            art.text
        );
    }
    Ok(())
}

fn cmd_compress(args: &Args) -> Result<(), String> {
    let [input, output] = two_paths(args)?;
    let symbols = std::fs::read(&input).map_err(|e| e.to_string())?;
    let hist = if symbols.is_empty() {
        Histogram::from_symbols(&[0])
    } else {
        Histogram::from_symbols(&symbols)
    };
    let codec = args.opt_or("codec", "qlc");
    let handle = CodecRegistry::global().resolve(&codec, &hist)?;
    let adaptive = args.has_flag("adaptive-chunks");
    if adaptive && handle.chunk_tables().is_none() {
        return Err(format!(
            "--adaptive-chunks needs a codec with per-chunk tables \
             (qlc family), not '{codec}'"
        ));
    }
    let encode = qlc::codecs::EncodeMode::parse(
        &args.opt_or("encode", "batched"),
    )?;
    let opts = FrameOptions { encode, ..Default::default() };
    let n_shards = args.opt_usize("shards", 0).map_err(|e| e.to_string())?;
    if n_shards > 0 {
        if args.has_flag("qlf1") {
            return Err(
                "--qlf1 and --shards are mutually exclusive (shards use \
                 the QLM1/QLS1 formats)"
                    .into(),
            );
        }
        if adaptive {
            return Err(
                "--adaptive-chunks applies to QLF2 frames only (shards \
                 share one manifest table)"
                    .into(),
            );
        }
        // Sharded: QLM1 manifest at <out>, shard bodies alongside.
        let (manifest, shards) = frame::compress_sharded(
            &handle,
            &symbols,
            n_shards,
            &opts,
        )
        .map_err(|e| e.to_string())?;
        std::fs::write(&output, manifest.to_bytes())
            .map_err(|e| e.to_string())?;
        let mut total = 0usize;
        for (i, body) in shards.iter().enumerate() {
            total += body.len();
            std::fs::write(shard_path(&output, i), body)
                .map_err(|e| e.to_string())?;
        }
        println!(
            "{} -> {} + {} shards: {} -> {} bytes ({:.1}% compressibility, \
             codec {})",
            input.display(),
            output.display(),
            shards.len(),
            symbols.len(),
            total,
            (1.0 - total as f64 / symbols.len().max(1) as f64) * 100.0,
            codec
        );
        return Ok(());
    }
    // QLF2 chunked frames by default (parallel encode/decode);
    // `--qlf1` writes the legacy single-payload format.
    let framed = if args.has_flag("qlf1") {
        if adaptive {
            return Err(
                "--adaptive-chunks applies to QLF2 frames only".into()
            );
        }
        frame::compress_qlf1(&handle, &symbols)
    } else if adaptive {
        frame::compress_adaptive(&handle, &symbols, &opts)
            .map_err(|e| e.to_string())?
    } else {
        frame::compress_with(&handle, &symbols, &opts)
            .map_err(|e| e.to_string())?
    };
    std::fs::write(&output, &framed).map_err(|e| e.to_string())?;
    println!(
        "{} -> {}: {} -> {} bytes ({:.1}% compressibility, codec {})",
        input.display(),
        output.display(),
        symbols.len(),
        framed.len(),
        (1.0 - framed.len() as f64 / symbols.len().max(1) as f64) * 100.0,
        codec
    );
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<(), String> {
    let [input, output] = two_paths(args)?;
    let decode = qlc::codecs::DecodeMode::parse(
        &args.opt_or("decode", "batched"),
    )?;
    let opts = FrameOptions { decode, ..Default::default() };
    let framed = std::fs::read(&input).map_err(|e| e.to_string())?;
    let symbols = if framed.len() >= 4 && framed[0..4] == frame::MAGIC_MANIFEST
    {
        // Sharded: the input is a manifest; shard files sit beside it.
        let manifest =
            ShardManifest::parse(&framed).map_err(|e| e.to_string())?;
        let mut shards = Vec::with_capacity(manifest.n_shards());
        for i in 0..manifest.n_shards() {
            let path = shard_path(&input, i);
            shards.push(std::fs::read(&path).map_err(|e| {
                format!("{}: {e}", path.display())
            })?);
        }
        frame::decompress_sharded(&manifest, &shards, &opts)
            .map_err(|e| e.to_string())?
    } else {
        frame::decompress_with(&framed, &opts).map_err(|e| e.to_string())?
    };
    std::fs::write(&output, &symbols).map_err(|e| e.to_string())?;
    println!(
        "{} -> {}: {} -> {} bytes",
        input.display(),
        output.display(),
        framed.len(),
        symbols.len()
    );
    Ok(())
}

/// `<base>.shardK` sibling path for shard `k` of a manifest at `base`.
fn shard_path(base: &Path, k: usize) -> PathBuf {
    let mut name = base.file_name().unwrap_or_default().to_os_string();
    name.push(format!(".shard{k}"));
    base.with_file_name(name)
}

fn two_paths(args: &Args) -> Result<[PathBuf; 2], String> {
    if args.positional.len() != 2 {
        return Err("expected <input> <output>".into());
    }
    Ok([
        PathBuf::from(&args.positional[0]),
        PathBuf::from(&args.positional[1]),
    ])
}

fn cmd_datagen(args: &Args) -> Result<(), String> {
    let kind_s = args.opt_or("kind", "ffn1_act");
    let kind =
        TensorKind::parse(&kind_s).ok_or(format!("bad kind {kind_s}"))?;
    let n = args.opt_usize("n", 1 << 20).map_err(|e| e.to_string())?;
    let seed = args.opt_u64("seed", 1).map_err(|e| e.to_string())?;
    let out =
        PathBuf::from(args.opt("out").ok_or("datagen requires --out DIR")?);
    let gen = if let Some(h) = args.opt("target-entropy") {
        let target: f64 = h.parse().map_err(|_| "bad --target-entropy")?;
        let (gen, achieved) = calibrate_generator(kind, target, seed, 0.02);
        println!("calibrated knob {:.4} → entropy {achieved:.3}", gen.knob);
        gen
    } else {
        let default = TensorGen::new(kind, Variant::ExmY);
        let knob = args
            .opt_f64("knob", default.knob)
            .map_err(|e| e.to_string())?;
        default.with_knob(knob)
    };
    let mut rng = Rng::new(seed);
    let symbols = gen.symbols(&mut rng, n - n % 32);
    let trace = Trace::new(&kind_s, symbols)
        .with_meta("kind", kind_s.as_str())
        .with_meta("seed", seed as usize)
        .with_meta("knob", gen.knob);
    trace.save(&out).map_err(|e| e.to_string())?;
    println!("wrote {}/{}.syms", out.display(), kind_s);
    Ok(())
}

fn cmd_optimize(args: &Args) -> Result<(), String> {
    let (label, symbols) = load_symbols(args)?;
    let pmf = Histogram::from_symbols(&symbols).pmf();
    let sorted = pmf.sorted_desc();
    let scheme = if let Some(p) = args.opt("prefix") {
        let p: u32 = p.parse().map_err(|_| "bad --prefix")?;
        optimizer::optimize_for_prefix(&sorted, p)
    } else {
        optimizer::optimize_scheme(&sorted)
    };
    let art = report::table_scheme("OPTIMIZED", &label, &scheme, &pmf);
    if args.has_flag("json") {
        println!("{}", art.json.to_string_pretty());
    } else {
        println!("{}", art.text);
    }
    Ok(())
}

fn cmd_collective(args: &Args) -> Result<(), String> {
    let trace_path = args.opt("trace");
    if trace_path.is_some() {
        obs::set_trace(true);
    }
    let op = args.opt_or("op", "allreduce");
    let workers = args.opt_usize("workers", 8).map_err(|e| e.to_string())?;
    if workers == 0 {
        return Err("--workers must be at least 1".into());
    }
    let size = args.opt_usize("size", 1 << 20).map_err(|e| e.to_string())?;
    let codec = args.opt_or("codec", "qlc");
    let seed = args.opt_u64("seed", 1).map_err(|e| e.to_string())?;
    // Start from a preset (default "pod": 50 GB/s, 2 µs — the old CLI
    // defaults), then let explicit flags override its numbers.
    let fabric_name = args.opt_or("fabric", "pod");
    let mut fabric = Fabric::preset(&fabric_name, workers)?;
    if args.opt("bandwidth-gbps").is_some() {
        let bw = args
            .opt_f64("bandwidth-gbps", 50.0)
            .map_err(|e| e.to_string())?;
        fabric.link_bandwidth = bw * 1e9;
    }
    if args.opt("latency-us").is_some() {
        let lat =
            args.opt_f64("latency-us", 2.0).map_err(|e| e.to_string())?;
        fabric.link_latency = lat * 1e-6;
    }
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(seed);
    let n = size - size % (workers * 32);
    let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 256 * 32));
    let transport = if codec == "raw" {
        Transport::Raw
    } else {
        Transport::Compressed {
            codec: codec.clone(),
            calibration: Box::new(cal),
        }
    };
    let report = match op.as_str() {
        "allreduce" => {
            let data: Vec<Vec<f32>> =
                (0..workers).map(|_| gen.generate(&mut rng, n)).collect();
            collective::ring_allreduce(&fabric, &data, &transport)?.1
        }
        "allgather" => {
            let shards: Vec<Vec<u8>> = (0..workers)
                .map(|_| gen.symbols(&mut rng, n / workers))
                .collect();
            let scales: Vec<Vec<f32>> = (0..workers)
                .map(|_| vec![1.0; n / workers / 32])
                .collect();
            collective::ring_allgather(&fabric, &shards, &scales, &transport)?
                .1
        }
        other => return Err(format!("unknown op {other}")),
    };
    let j = Json::obj()
        .set("op", report.op.as_str())
        .set("transport", report.transport.as_str())
        .set("fabric", fabric_name.as_str())
        // Effective link numbers (presets can be overridden by flags).
        .set("link_bandwidth_gbps", fabric.link_bandwidth / 1e9)
        .set("link_latency_us", fabric.link_latency * 1e6)
        .set("workers", workers)
        .set("steps", report.steps)
        .set("wire_bytes", report.wire_bytes as usize)
        .set("raw_bytes", report.raw_bytes as usize)
        .set("compression_ratio", report.compression_ratio())
        .set("network_time_s", report.network_time_s)
        .set("codec_time_s", report.codec_time_s)
        .set("total_time_s", report.total_time_s())
        .set("pipelined_time_s", report.pipelined_time_s)
        .set("overlap_savings", report.overlap_savings());
    if args.has_flag("json") {
        println!("{}", j.to_string_pretty());
    } else {
        println!(
            "{} x{} via {} on {}: {} steps, wire {} B (ratio {:.3}), \
             network {:.3} ms, codec {:.3} ms, total {:.3} ms, pipelined \
             {:.3} ms ({:.0}% overlap savings)",
            report.op,
            workers,
            report.transport,
            fabric_name,
            report.steps,
            report.wire_bytes,
            report.compression_ratio(),
            report.network_time_s * 1e3,
            report.codec_time_s * 1e3,
            report.total_time_s() * 1e3,
            report.pipelined_time_s * 1e3,
            report.overlap_savings() * 100.0,
        );
    }
    if let Some(path) = trace_path {
        obs::write_trace(Path::new(path), 0, "collective-sim")
            .map_err(|e| format!("{path}: {e}"))?;
        eprintln!("trace -> {path}");
    }
    if let Some(path) = args.opt("metrics") {
        let art = report::obs_artifact("OBS", &obs::global().snapshot());
        let body = if path.ends_with(".json") {
            art.json.to_string_pretty()
        } else {
            art.text
        };
        std::fs::write(path, body).map_err(|e| format!("{path}: {e}"))?;
        eprintln!("metrics -> {path}");
    }
    Ok(())
}

fn cmd_hw(args: &Args) -> Result<(), String> {
    let seed = args.opt_u64("seed", 42).map_err(|e| e.to_string())?;
    let n = args.opt_usize("n", 1 << 20).map_err(|e| e.to_string())?;
    let pmfs = report::paper_pmfs(seed, 6);
    let mut out = Vec::new();
    for (label, pmf, hist, scheme) in [
        (
            "ffn1",
            &pmfs.ffn1,
            &pmfs.ffn1_hist,
            qlc::codecs::qlc::AreaScheme::table1(),
        ),
        (
            "ffn2",
            &pmfs.ffn2,
            &pmfs.ffn2_hist,
            qlc::codecs::qlc::AreaScheme::table2(),
        ),
    ] {
        let symbols = report::sample_symbols(pmf, n, seed ^ 7);
        let huff = HuffmanCodec::from_histogram(hist);
        let qlc_codec = QlcCodec::from_pmf(scheme, pmf);
        let reports = hw::compare_on_stream(huff.book(), &qlc_codec, &symbols);
        let speedup = hw::qlc_speedup_vs_serial(&reports);
        println!("--- {label} ({} symbols) ---", symbols.len());
        for r in &reports {
            println!(
                "  {:<16} {:>8.3} cycles/sym  storage {:>8} bits  stages {}",
                r.model,
                r.cycles_per_symbol(),
                r.storage_bits,
                r.worst_stages
            );
        }
        println!("  QLC speedup vs bit-serial Huffman: {speedup:.2}x");
        out.push(
            Json::obj().set("label", label).set("speedup", speedup).set(
                "reports",
                Json::Arr(
                    reports
                        .iter()
                        .map(|r| {
                            Json::obj()
                                .set("model", r.model.as_str())
                                .set(
                                    "cycles_per_symbol",
                                    r.cycles_per_symbol(),
                                )
                                .set("storage_bits", r.storage_bits as usize)
                                .set("stages", r.worst_stages as usize)
                        })
                        .collect(),
                ),
            ),
        );
    }
    if args.has_flag("json") {
        println!("{}", Json::Arr(out).to_string_pretty());
    }
    Ok(())
}

fn cmd_formats(args: &Args) -> Result<(), String> {
    use qlc::codecs::qlc::optimizer;
    use qlc::formats::{ExmyFormat, ExmySpec};
    let n = args.opt_usize("n", 1 << 20).map_err(|e| e.to_string())?;
    let seed = args.opt_u64("seed", 17).map_err(|e| e.to_string())?;
    let mut rng = Rng::new(seed);
    let mut data = vec![0f32; n - n % 32];
    rng.fill_normal_f32(&mut data, 0.0, 1.0);
    println!(
        "{:>8} {:>9} {:>9} {:>9}",
        "format", "entropy", "ideal%", "qlc-opt%"
    );
    for spec in [ExmySpec::E2M5, ExmySpec::E3M4, ExmySpec::E4M3,
                 ExmySpec::E5M2] {
        let f = ExmyFormat::new(spec);
        let (symbols, _) = f.quantize_blocks(&data);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let sorted = pmf.sorted_desc();
        let opt = optimizer::optimize_scheme(&sorted);
        println!(
            "{:>8} {:>9.3} {:>9.2} {:>9.2}",
            spec.name(),
            pmf.entropy(),
            pmf.ideal_compressibility() * 100.0,
            opt.compressibility_sorted(&sorted) * 100.0
        );
    }
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_harvest(_args: &Args) -> Result<(), String> {
    Err("harvest needs the PJRT runtime: rebuild with --features pjrt \
         (and the xla/anyhow dependencies; see rust/Cargo.toml)"
        .into())
}

#[cfg(feature = "pjrt")]
fn cmd_harvest(args: &Args) -> Result<(), String> {
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let out = PathBuf::from(args.opt("out").ok_or("harvest requires --out")?);
    let steps = args.opt_usize("steps", 4).map_err(|e| e.to_string())?;
    let seed = args.opt_u64("seed", 1).map_err(|e| e.to_string())?;
    let rt = Runtime::load(&artifacts).map_err(|e| e.to_string())?;
    let mut rng = Rng::new(seed);
    let mut streams: std::collections::BTreeMap<String, Vec<u8>> =
        Default::default();
    for step in 0..steps {
        let ins = qlc::runtime::inputs::make_step_inputs(
            rt.input_shapes(),
            InputStats::default(),
            &mut rng,
        );
        let tensors = rt.harvest_step(&ins).map_err(|e| e.to_string())?;
        for t in tensors {
            streams.entry(t.name).or_default().extend(t.symbols);
        }
        println!("step {step} done");
    }
    for (name, symbols) in streams {
        let pmf = Histogram::from_symbols(&symbols).pmf();
        println!(
            "{name}: {} symbols, entropy {:.3} bits, p(zero) {:.3}",
            symbols.len(),
            pmf.entropy(),
            pmf.p[0]
        );
        Trace::new(&name, symbols)
            .with_meta("source", "pjrt-harvest")
            .with_meta("seed", seed as usize)
            .save(&out)
            .map_err(|e| e.to_string())?;
    }
    println!("traces written to {}", out.display());
    Ok(())
}

fn cmd_pipeline(args: &Args) -> Result<(), String> {
    let codec = args.opt_or("codec", "qlc");
    let workers = args.opt_usize("workers", 4).map_err(|e| e.to_string())?;
    let chunk =
        args.opt_usize("chunk", 64 * 1024).map_err(|e| e.to_string())?;
    let n = args.opt_usize("n", 16 << 20).map_err(|e| e.to_string())?;
    let seed = args.opt_u64("seed", 1).map_err(|e| e.to_string())?;
    let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
    let mut rng = Rng::new(seed);
    let symbols = gen.symbols(&mut rng, n - n % 32);
    let hist = Histogram::from_symbols(&symbols);
    let pipe = Pipeline::new(
        PipelineConfig {
            workers,
            chunk_size: chunk,
            queue_depth: workers * 2,
        },
        &codec,
        &hist,
    )?;
    let n_shards = args.opt_usize("shards", 0).map_err(|e| e.to_string())?;
    let t0 = std::time::Instant::now();
    let (label, units) = if n_shards > 0 {
        let (manifest, shards) = pipe.compress_sharded(&symbols, n_shards)?;
        println!(
            "manifest: {} shards, {} header bytes shared once",
            manifest.n_shards(),
            manifest.wire_header().len()
        );
        ("shards", shards.len())
    } else {
        ("jobs", pipe.compress_stream(&symbols)?.len())
    };
    let wall = t0.elapsed().as_secs_f64();
    let m = pipe.metrics();
    println!(
        "pipeline: {} {label}, {} -> {} bytes ({:.1}% compressibility)\n\
         wall {:.3}s  ({:.1} MB/s end-to-end, {:.1} MB/s aggregate codec)",
        units,
        m.input_bytes,
        m.output_bytes,
        m.compressibility().unwrap_or(0.0) * 100.0,
        wall,
        m.input_bytes as f64 / wall / 1e6,
        m.throughput_mbps().unwrap_or(0.0)
    );
    Ok(())
}

// ---------------------------------------------------------------------------
// Streaming compression service

/// Shared `--reactor` / `--timeout-s` parsing for the serve-family
/// subcommands.
fn reactor_arg(
    args: &Args,
) -> Result<qlc::transport::reactor::Backend, String> {
    qlc::transport::reactor::Backend::parse(&args.opt_or("reactor", "auto"))
}

fn timeout_arg(args: &Args) -> Result<std::time::Duration, String> {
    let timeout_s =
        args.opt_f64("timeout-s", 30.0).map_err(|e| e.to_string())?;
    if !timeout_s.is_finite() || timeout_s <= 0.0 {
        return Err("--timeout-s must be a positive number".into());
    }
    Ok(std::time::Duration::from_secs_f64(timeout_s))
}

fn cmd_serve(args: &Args) -> Result<(), String> {
    use qlc::serve::{Server, ServerConfig};
    let listen = args.opt_or("listen", "127.0.0.1:0");
    let cfg = ServerConfig {
        backend: reactor_arg(args)?,
        max_requests: args
            .opt_u64("max-requests", 0)
            .map_err(|e| e.to_string())?,
        max_conns: args
            .opt_usize("max-conns", 256)
            .map_err(|e| e.to_string())?,
        ..ServerConfig::default()
    };
    let trace_path = args.opt("trace");
    if trace_path.is_some() {
        obs::set_trace(true);
    }
    let mut server = Server::bind(&listen, cfg)?;
    println!(
        "serving on {} (reactor {})",
        server.local_addr(),
        server.backend_name()
    );
    // Scripts wait for this line to learn the bound port; make sure
    // it is visible before the (potentially long) event loop starts.
    use std::io::Write as _;
    let _ = std::io::stdout().flush();
    let summary = server.run()?;
    if let Some(path) = trace_path {
        obs::write_trace(Path::new(path), 0, "serve")
            .map_err(|e| format!("{path}: {e}"))?;
    }
    if let Some(path) = args.opt("metrics") {
        obs::write_metrics(Path::new(path), &obs::global().snapshot())
            .map_err(|e| format!("{path}: {e}"))?;
    }
    println!(
        "served {} requests over {} connections",
        summary.requests, summary.conns
    );
    Ok(())
}

fn cmd_call(args: &Args) -> Result<(), String> {
    use qlc::serve::{
        chunks_from_raw, concat_payloads, ClientConfig, ServeClient,
    };
    use qlc::transport::net::serve_wire::{self, Handshake, Op};
    use qlc::transport::net::wire;
    let [input, output] = two_paths(args)?;
    let addr = args.require("connect").map_err(|e| e.to_string())?;
    let op = Op::parse(&args.opt_or("op", "compress"))?;
    let cfg = ClientConfig {
        backend: reactor_arg(args)?,
        timeout: timeout_arg(args)?,
        chunk: args
            .opt_usize("chunk", 64 * 1024)
            .map_err(|e| e.to_string())?,
    };
    let data = std::fs::read(&input)
        .map_err(|e| format!("{}: {e}", input.display()))?;
    match op {
        Op::Compress => {
            let hist = Histogram::from_symbols(&data);
            let handle = CodecRegistry::global()
                .resolve(&args.opt_or("codec", "qlc"), &hist)?;
            let mut client =
                ServeClient::connect(addr, &handle, Op::Compress, &cfg)?;
            let responses = client.request(&chunks_from_raw(
                &data, cfg.chunk,
            ))?;
            // Self-describing container: the codec identity (the same
            // handshake the server saw) followed by the compressed
            // response frames, so `--op decompress` can replay it
            // against any server without outside context.
            let mut out = Vec::new();
            serve_wire::encode_handshake(
                &Handshake {
                    op: Op::Compress,
                    codec_tag: handle.wire_tag(),
                    header: handle.wire_header().to_vec(),
                },
                &mut out,
            )?;
            let mut payload_bytes = 0usize;
            for c in &responses {
                payload_bytes += c.payload.len();
                wire::encode_frame(0, handle.wire_tag(), c, &mut out)?;
            }
            std::fs::write(&output, &out)
                .map_err(|e| format!("{}: {e}", output.display()))?;
            println!(
                "compressed {} -> {} payload bytes ({} with framing) \
                 via reactor {}",
                data.len(),
                payload_bytes,
                out.len(),
                client.backend_name()
            );
        }
        Op::Decompress => {
            let Some((hs, used)) = serve_wire::decode_handshake(&data)?
            else {
                return Err(
                    "input is not a qlc call container (truncated \
                     handshake)"
                        .into(),
                );
            };
            let handle = CodecRegistry::global()
                .resolve_wire(hs.codec_tag, &hs.header)
                .map_err(|e| e.to_string())?;
            let mut chunks = Vec::new();
            let mut pos = used;
            while pos < data.len() {
                match wire::decode_frame(&data[pos..])? {
                    Some((frame, n)) => {
                        pos += n;
                        chunks.push(frame.msg);
                    }
                    None => {
                        return Err("container ends mid-frame".into())
                    }
                }
            }
            let mut client =
                ServeClient::connect(addr, &handle, Op::Decompress, &cfg)?;
            let responses = client.request(&chunks)?;
            let raw = concat_payloads(&responses);
            std::fs::write(&output, &raw)
                .map_err(|e| format!("{}: {e}", output.display()))?;
            println!(
                "decompressed {} container bytes -> {} raw bytes via \
                 reactor {}",
                data.len(),
                raw.len(),
                client.backend_name()
            );
        }
    }
    Ok(())
}

fn loadgen_json(r: &qlc::serve::LoadgenReport) -> Json {
    Json::obj()
        .set("streams", r.streams)
        .set("requests", r.requests as usize)
        .set("raw_bytes", r.raw_bytes as usize)
        .set("wire_bytes", r.wire_bytes as usize)
        .set("wall_s", r.wall_s)
        .set("aggregate_mbps", r.aggregate_mbps)
        .set("verified", r.verified as usize)
        .set("p50_compress_ns", r.p50_compress_ns as usize)
        .set("p99_compress_ns", r.p99_compress_ns as usize)
        .set("p50_decompress_ns", r.p50_decompress_ns as usize)
        .set("p99_decompress_ns", r.p99_decompress_ns as usize)
        .set("backend", r.backend.as_str())
}

fn print_loadgen(addr: &str, r: &qlc::serve::LoadgenReport) {
    println!(
        "loadgen x{} on {addr} (reactor {}): {} round trips ({} \
         verified), raw {:.1} MB, wire {:.1} MB, {:.1} MB/s aggregate\n\
         compress p50 {:.3} ms p99 {:.3} ms; decompress p50 {:.3} ms \
         p99 {:.3} ms",
        r.streams,
        r.backend,
        r.requests,
        r.verified,
        r.raw_bytes as f64 / 1e6,
        r.wire_bytes as f64 / 1e6,
        r.aggregate_mbps,
        r.p50_compress_ns as f64 / 1e6,
        r.p99_compress_ns as f64 / 1e6,
        r.p50_decompress_ns as f64 / 1e6,
        r.p99_decompress_ns as f64 / 1e6,
    );
}

fn cmd_loadgen(args: &Args) -> Result<(), String> {
    use qlc::serve::{run_loadgen, LoadgenConfig};
    let base = LoadgenConfig {
        addr: String::new(),
        streams: args.opt_usize("streams", 4).map_err(|e| e.to_string())?,
        requests: args.opt_usize("requests", 8).map_err(|e| e.to_string())?,
        size: args.opt_usize("size", 1 << 20).map_err(|e| e.to_string())?,
        chunk: args
            .opt_usize("chunk", 64 * 1024)
            .map_err(|e| e.to_string())?,
        codec: args.opt_or("codec", "qlc"),
        backend: reactor_arg(args)?,
        verify: args.has_flag("verify"),
        seed: args.opt_u64("seed", 0x10ad).map_err(|e| e.to_string())?,
        timeout: timeout_arg(args)?,
    };
    if args.has_flag("bench") {
        return loadgen_bench(args, base);
    }
    let addr = args.require("connect").map_err(|e| e.to_string())?;
    let cfg = LoadgenConfig { addr: addr.to_string(), ..base };
    let report = run_loadgen(&cfg)?;
    if args.has_flag("json") {
        println!("{}", loadgen_json(&report).to_string_pretty());
    } else {
        print_loadgen(addr, &report);
    }
    Ok(())
}

/// `qlc loadgen --bench`: run the same verified load against an
/// in-process server once per reactor backend and record the
/// comparison (BENCH_9.json).  Gate: epoll aggregate throughput must
/// not lose to the sleep-polling fallback.
fn loadgen_bench(
    args: &Args,
    base: qlc::serve::LoadgenConfig,
) -> Result<(), String> {
    use qlc::serve::{run_loadgen, LoadgenConfig, Server, ServerConfig};
    use qlc::transport::reactor;
    use std::sync::atomic::Ordering;

    let mut backends = vec![reactor::Backend::Fallback];
    if reactor::epoll_available() {
        backends.push(reactor::Backend::Epoll);
    }
    let mut reports = Vec::new();
    for be in backends {
        let mut server = Server::bind(
            "127.0.0.1:0",
            ServerConfig { backend: be, ..ServerConfig::default() },
        )?;
        let addr = server.local_addr().to_string();
        let stop = server.shutdown_handle();
        let handle = std::thread::spawn(move || server.run());
        let cfg = LoadgenConfig {
            addr: addr.clone(),
            backend: be,
            verify: true,
            ..base.clone()
        };
        let res = run_loadgen(&cfg);
        stop.store(true, Ordering::Relaxed);
        let server_res = handle.join().unwrap_or_else(|_| {
            Err("server thread panicked".to_string())
        });
        let report = res?;
        server_res?;
        print_loadgen(&addr, &report);
        reports.push(report);
    }

    let mbps = |name: &str| {
        reports
            .iter()
            .find(|r| r.backend == name)
            .map(|r| r.aggregate_mbps)
    };
    let mut gate_failures: Vec<String> = Vec::new();
    if let (Some(fallback), Some(epoll)) = (mbps("fallback"), mbps("epoll"))
    {
        if epoll < fallback {
            gate_failures.push(format!(
                "serve roundtrip: epoll {epoll:.1} MB/s < fallback \
                 {fallback:.1} MB/s at M={}",
                base.streams
            ));
        }
    }

    let mut latency = Vec::new();
    for r in &reports {
        for (op, p50, p99) in [
            ("compress", r.p50_compress_ns, r.p99_compress_ns),
            ("decompress", r.p50_decompress_ns, r.p99_decompress_ns),
        ] {
            latency.push(
                Json::obj()
                    .set(
                        "metric",
                        obs::label(
                            "serve_request_latency_ns",
                            &[("backend", r.backend.as_str()), ("op", op)],
                        )
                        .as_str(),
                    )
                    .set("p50_ns", p50 as usize)
                    .set("p99_ns", p99 as usize),
            );
        }
    }
    let doc = Json::obj()
        .set("bench", "serve_loadgen")
        .set("streams", base.streams)
        .set("requests", base.requests)
        .set("size", base.size)
        .set(
            "results",
            Json::Arr(
                reports
                    .iter()
                    .map(|r| {
                        Json::obj()
                            .set(
                                "name",
                                format!("serve_roundtrip_{}", r.backend)
                                    .as_str(),
                            )
                            .set("mbps", r.aggregate_mbps)
                    })
                    .collect(),
            ),
        )
        .set("latency", Json::Arr(latency))
        .set(
            "gate_failures",
            Json::Arr(
                gate_failures
                    .iter()
                    .map(|s| Json::Str(s.clone()))
                    .collect(),
            ),
        );
    let out_path = match args.opt("out") {
        Some(p) => p.to_string(),
        None => std::env::var("QLC_BENCH_JSON")
            .unwrap_or_else(|_| "BENCH_9.json".to_string()),
    };
    std::fs::write(&out_path, doc.to_string_pretty())
        .map_err(|e| format!("{out_path}: {e}"))?;
    println!("wrote {out_path}");
    if !gate_failures.is_empty() {
        eprintln!(
            "FAIL: serve perf gate (epoll ≥ fallback):\n  {}",
            gate_failures.join("\n  ")
        );
        if std::env::var("QLC_BENCH_SMOKE").is_ok() {
            return Err("serve bench gate failed".into());
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Distributed TCP collectives

/// Parse the worker/launch options shared by both subcommands into a
/// [`dist::WorkerConfig`] template (rank and rendezvous address are
/// filled in by the caller).
fn dist_template(
    args: &Args,
    world: usize,
) -> Result<qlc::collective::dist::WorkerConfig, String> {
    use qlc::collective::dist::{self, DistOp, WorkerConfig};
    let op = DistOp::parse(&args.opt_or("op", "allreduce"))?;
    let size = args.opt_usize("size", 1 << 18).map_err(|e| e.to_string())?;
    let chunk = args
        .opt_usize("chunk", qlc::transport::DEFAULT_TRANSPORT_CHUNK)
        .map_err(|e| e.to_string())?;
    let seed = args.opt_u64("seed", 1).map_err(|e| e.to_string())?;
    let timeout_s =
        args.opt_f64("timeout-s", 30.0).map_err(|e| e.to_string())?;
    if !timeout_s.is_finite() || timeout_s <= 0.0 {
        return Err("--timeout-s must be a positive number".into());
    }
    let mut cfg = WorkerConfig::new(0, world, String::new());
    cfg.op = op;
    cfg.codec = args.opt_or("codec", "qlc");
    cfg.elems = dist::round_size(size, world)?;
    cfg.chunk_symbols = chunk.max(1);
    cfg.seed = seed;
    cfg.timeout = std::time::Duration::from_secs_f64(timeout_s);
    Ok(cfg)
}

fn worker_json(
    outcome: &qlc::collective::dist::DistOutcome,
    world: usize,
) -> Json {
    let r = &outcome.report;
    Json::obj()
        .set("rank", outcome.rank)
        .set("world", world)
        .set("op", r.op.as_str())
        .set("transport", r.transport.as_str())
        .set("steps", r.steps)
        .set("wire_bytes", r.wire_bytes as usize)
        .set("raw_bytes", r.raw_bytes as usize)
        .set("compression_ratio", r.compression_ratio())
        .set("codec_time_s", r.codec_time_s)
        .set("network_time_s", r.network_time_s)
        .set("total_time_s", r.total_time_s())
        .set("pipelined_time_s", r.pipelined_time_s)
        .set("overlap_savings", r.overlap_savings())
        .set("checksum", format!("{:016x}", outcome.checksum))
}

fn cmd_worker(args: &Args) -> Result<(), String> {
    use qlc::collective::dist;
    let world: usize = args
        .require("world")
        .map_err(|e| e.to_string())?
        .parse()
        .map_err(|_| "--world expects an integer".to_string())?;
    if world == 0 {
        return Err("--world must be at least 1".into());
    }
    let rank = args.opt_usize("rank", 0).map_err(|e| e.to_string())?;
    if rank >= world {
        return Err(format!("--rank {rank} out of range for world {world}"));
    }
    let listen = args.opt("listen");
    let connect = args.opt("connect");
    let addr = if world == 1 {
        String::new()
    } else if rank == 0 {
        match (listen, connect) {
            (Some(a), None) => a.to_string(),
            (None, None) => {
                return Err("rank 0 requires --listen ADDR".into())
            }
            _ => {
                return Err(
                    "rank 0 listens for the rendezvous; --connect is for \
                     ranks > 0"
                        .into(),
                )
            }
        }
    } else {
        match (listen, connect) {
            (None, Some(a)) => a.to_string(),
            (None, None) => {
                return Err(format!("rank {rank} requires --connect ADDR"))
            }
            _ => return Err("only rank 0 may --listen".into()),
        }
    };
    let mut cfg = dist_template(args, world)?;
    cfg.rank = rank;
    cfg.addr = addr;
    // Tracing must be armed before the ring forms so the rendezvous
    // and every hop land in the ring buffers.
    let trace_path = args.opt("trace");
    if trace_path.is_some() {
        obs::set_trace(true);
    }
    let outcome = dist::run_worker(&cfg)?;
    if let Some(path) = args.opt("out") {
        std::fs::write(path, &outcome.result_bytes)
            .map_err(|e| format!("{path}: {e}"))?;
    }
    if let Some(path) = trace_path {
        // One pid per rank: `qlc launch` merges the per-rank traces
        // into a single world-level timeline.
        obs::write_trace(
            Path::new(path),
            rank as u64,
            &format!("rank {rank}"),
        )
        .map_err(|e| format!("{path}: {e}"))?;
    }
    if let Some(path) = args.opt("metrics") {
        obs::write_metrics(Path::new(path), &obs::global().snapshot())
            .map_err(|e| format!("{path}: {e}"))?;
    }
    let r = &outcome.report;
    if args.has_flag("json") {
        println!("{}", worker_json(&outcome, world).to_string_pretty());
    } else {
        println!(
            "rank {rank}/{world} {} via {}: {} steps, wire {} B (ratio \
             {:.3}), wall {:.3} ms pipelined vs {:.3} ms serial ({:.0}% \
             hidden), checksum {:016x}",
            r.op,
            r.transport,
            r.steps,
            r.wire_bytes,
            r.compression_ratio(),
            r.pipelined_time_s * 1e3,
            r.total_time_s() * 1e3,
            r.overlap_savings() * 100.0,
            outcome.checksum,
        );
    }
    Ok(())
}

fn cmd_launch(args: &Args) -> Result<(), String> {
    use qlc::collective::dist;
    let world = args.opt_usize("world", 4).map_err(|e| e.to_string())?;
    if world == 0 {
        return Err("--world must be at least 1".into());
    }
    // Validate the shared options up front so a bad flag fails here,
    // not in N children.
    let template = dist_template(args, world)?;
    let addr = dist::free_loopback_addr()?;
    let exe = std::env::current_exe()
        .map_err(|e| format!("cannot locate the qlc binary: {e}"))?;
    let timeout_s = template.timeout.as_secs_f64();
    // Every spawned worker goes straight into a kill-on-drop Fleet:
    // any `?` below (spawn failure mid-roster, a wait error, garbage
    // output) reaps the rest of the fleet instead of leaking workers
    // that would otherwise linger until their own timeouts.
    let mut fleet = dist::Fleet::new();
    for rank in 0..world {
        let mut argv: Vec<String> = vec![
            "worker".to_string(),
            "--world".to_string(),
            world.to_string(),
            "--rank".to_string(),
            rank.to_string(),
            "--op".to_string(),
            args.opt_or("op", "allreduce"),
            "--codec".to_string(),
            template.codec.clone(),
            "--size".to_string(),
            template.elems.to_string(),
            "--chunk".to_string(),
            template.chunk_symbols.to_string(),
            "--seed".to_string(),
            template.seed.to_string(),
            "--timeout-s".to_string(),
            timeout_s.to_string(),
            "--json".to_string(),
        ];
        if world > 1 {
            let role = if rank == 0 { "--listen" } else { "--connect" };
            argv.push(role.to_string());
            argv.push(addr.clone());
        }
        // Per-rank observability temps; merged (and removed) below.
        if let Some(t) = args.opt("trace") {
            argv.push("--trace".to_string());
            argv.push(format!("{t}.rank{rank}"));
        }
        if let Some(m) = args.opt("metrics") {
            argv.push("--metrics".to_string());
            argv.push(format!("{m}.rank{rank}.json"));
        }
        let mut cmd = std::process::Command::new(&exe);
        cmd.args(argv);
        cmd.stdout(std::process::Stdio::piped());
        cmd.stderr(std::process::Stdio::piped());
        fleet.push(cmd.spawn().map_err(|e| format!("spawn rank {rank}: {e}"))?);
    }
    // Poll the whole fleet so one rank's failure surfaces immediately
    // (and kills the rest) instead of stalling behind rank 0's full
    // rendezvous timeout and leaking orphan workers.
    let mut outputs: Vec<Option<std::process::Output>> =
        (0..world).map(|_| None).collect();
    let mut failed: Option<(usize, String)> = None;
    let mut remaining = world;
    while remaining > 0 && failed.is_none() {
        let mut progressed = false;
        for rank in 0..world {
            if outputs[rank].is_some() {
                continue;
            }
            let Some(status) = fleet.try_wait(rank)? else { continue };
            let out = fleet.take_output(rank)?;
            remaining -= 1;
            progressed = true;
            if !status.success() {
                failed = Some((
                    rank,
                    String::from_utf8_lossy(&out.stderr)
                        .trim()
                        .to_string(),
                ));
                break;
            }
            outputs[rank] = Some(out);
        }
        if failed.is_none() && remaining > 0 && !progressed {
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
    }
    if let Some((rank, stderr)) = failed {
        fleet.kill_all();
        return Err(format!("worker rank {rank} failed: {stderr}"));
    }
    let mut reports: Vec<Json> = Vec::with_capacity(world);
    for (rank, out) in outputs.into_iter().enumerate() {
        let out = out.expect("all workers reaped");
        let text = String::from_utf8_lossy(&out.stdout);
        let json = Json::parse(text.trim()).map_err(|e| {
            format!("rank {rank} emitted unparseable output: {e}")
        })?;
        reports.push(json);
    }
    // The acceptance bar: every rank finished with the same bits.
    let checksum = reports[0]
        .get("checksum")
        .and_then(|j| j.as_str())
        .ok_or("worker output missing checksum")?
        .to_string();
    for (rank, j) in reports.iter().enumerate() {
        let c = j
            .get("checksum")
            .and_then(|x| x.as_str())
            .ok_or("worker output missing checksum")?;
        if c != checksum {
            return Err(format!(
                "rank {rank} checksum {c} != rank 0 checksum {checksum} \
                 — distributed result diverged"
            ));
        }
    }
    // Merge the per-rank observability temps into world-level files:
    // trace events concatenate (each rank already carries its own
    // pid), metric snapshots fold counter-wise/bucket-wise.
    if let Some(t) = args.opt("trace") {
        let mut parts = Vec::with_capacity(world);
        for rank in 0..world {
            let path = format!("{t}.rank{rank}");
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("{path}: {e}"))?;
            parts.push(
                Json::parse(&text).map_err(|e| format!("{path}: {e}"))?,
            );
            let _ = std::fs::remove_file(&path);
        }
        let merged = obs::merge_chrome_traces(&parts);
        std::fs::write(t, merged.to_string_pretty())
            .map_err(|e| format!("{t}: {e}"))?;
        eprintln!("world trace ({world} ranks) -> {t}");
    }
    if let Some(m) = args.opt("metrics") {
        let mut merged = obs::Snapshot::default();
        for rank in 0..world {
            let path = format!("{m}.rank{rank}.json");
            let text = std::fs::read_to_string(&path)
                .map_err(|e| format!("{path}: {e}"))?;
            merged.merge(
                &obs::Snapshot::parse(&text)
                    .map_err(|e| format!("{path}: {e}"))?,
            );
            let _ = std::fs::remove_file(&path);
        }
        obs::write_metrics(Path::new(m), &merged)
            .map_err(|e| format!("{m}: {e}"))?;
        eprintln!("world metrics ({world} ranks) -> {m}");
    }
    let scalar = |k: &str| -> f64 {
        reports[0].get(k).and_then(|j| j.as_f64()).unwrap_or(0.0)
    };
    let pipelined = scalar("pipelined_time_s");
    let total = scalar("total_time_s");
    let wire = scalar("wire_bytes");
    let ratio = scalar("compression_ratio");
    drop(scalar);
    if args.has_flag("json") {
        let j = Json::obj()
            .set("world", world)
            .set("agree", true)
            .set("checksum", checksum.as_str())
            .set("rank0", reports.remove(0));
        println!("{}", j.to_string_pretty());
    } else {
        let hidden = if total > 0.0 {
            (1.0 - pipelined / total).max(0.0)
        } else {
            0.0
        };
        println!(
            "launch x{world} on 127.0.0.1: all ranks agree (checksum \
             {checksum}); rank 0: wire {} B (ratio {ratio:.3}), wall \
             {:.3} ms pipelined vs {:.3} ms serial ({:.0}% hidden)",
            wire as u64,
            pipelined * 1e3,
            total * 1e3,
            hidden * 100.0,
        );
    }
    Ok(())
}
