//! bfloat16-precision GELU — the source of the paper's FFN2 zero
//! spike.
//!
//! In mixed-precision training the GELU's tanh saturates to exactly
//! −1 in bf16 for sufficiently negative pre-activations, so the
//! activation output is exactly zero.  A pure-f32 GELU never reaches
//! zero and would miss Fig. 4's dominant symbol entirely.  Mirrors
//! `python/compile/model.py::_gelu_bf16`.

/// Round an f32 to bfloat16 precision (round-to-nearest-even).
#[inline]
pub fn round_bf16(x: f32) -> f32 {
    let bits = x.to_bits();
    let round = 0x7FFFu32 + ((bits >> 16) & 1);
    f32::from_bits((bits.wrapping_add(round)) & 0xFFFF_0000)
}

/// tanh-approximation GELU evaluated at bf16 precision.
pub fn gelu_bf16(x: f32) -> f32 {
    let x = round_bf16(x);
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    let inner = round_bf16(c * (x + 0.044715 * x * x * x));
    let t = round_bf16(inner.tanh());
    round_bf16(0.5 * x * round_bf16(1.0 + t))
}

/// d/dx of the tanh-approximation GELU, also at bf16 precision
/// (zero wherever the forward saturated — gradients share the spike).
pub fn gelu_prime_bf16(x: f32) -> f32 {
    let x = round_bf16(x);
    let c = (2.0f32 / std::f32::consts::PI).sqrt();
    let inner = round_bf16(c * (x + 0.044715 * x * x * x));
    let t = round_bf16(inner.tanh());
    let sech2 = round_bf16(1.0 - t * t);
    let dinner = round_bf16(c * (1.0 + 3.0 * 0.044715 * x * x));
    round_bf16(0.5 * round_bf16(1.0 + t) + 0.5 * x * sech2 * dinner)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_bf16_exact_values() {
        assert_eq!(round_bf16(1.0), 1.0);
        assert_eq!(round_bf16(0.0), 0.0);
        assert_eq!(round_bf16(-2.0), -2.0);
    }

    #[test]
    fn round_bf16_drops_mantissa() {
        // 1 + 2^-10 rounds to 1.0 in bf16 (7 mantissa bits).
        assert_eq!(round_bf16(1.0 + 2.0f32.powi(-10)), 1.0);
        // 1 + 2^-7 is representable.
        assert_eq!(round_bf16(1.0 + 2.0f32.powi(-7)), 1.0 + 2.0f32.powi(-7));
    }

    #[test]
    fn round_to_nearest_even() {
        // Midpoint between 1.0 and 1+2^-7: 1+2^-8 → even (1.0).
        assert_eq!(round_bf16(1.0 + 2.0f32.powi(-8)), 1.0);
        // Midpoint between 1+2^-7 and 1+2^-6 → even (1+2^-6).
        assert_eq!(
            round_bf16(1.0 + 3.0 * 2.0f32.powi(-8)),
            1.0 + 2.0f32.powi(-6)
        );
    }

    #[test]
    fn gelu_saturates_to_exact_zero() {
        let mut saw_zero = false;
        for i in 0..64 {
            let x = -8.0 + 0.0625 * i as f32; // [-8, -4)
            if gelu_bf16(x) == 0.0 {
                saw_zero = true;
            }
        }
        assert!(saw_zero, "bf16 GELU must emit exact zeros in the tail");
    }

    #[test]
    fn bf16_saturates_earlier_than_f32() {
        // The bf16 zero-threshold (the onset of the paper's spike) must
        // sit well above the f32 one: more of the input distribution
        // maps to exact zero.
        let f32_gelu = |x: f32| {
            let c = (2.0f32 / std::f32::consts::PI).sqrt();
            0.5 * x * (1.0 + (c * (x + 0.044715 * x * x * x)).tanh())
        };
        let first_zero = |f: &dyn Fn(f32) -> f32| {
            let mut t = -12.0f32;
            while t < 0.0 && f(t) == 0.0 {
                t += 0.01;
            }
            t // first x (from below) where f(x) != 0
        };
        let bf16_onset = first_zero(&|x| gelu_bf16(x));
        let f32_onset = first_zero(&f32_gelu);
        assert!(
            bf16_onset > f32_onset + 0.5,
            "bf16 onset {bf16_onset} vs f32 onset {f32_onset}"
        );
    }

    #[test]
    fn gelu_identity_like_for_positive() {
        for x in [1.0f32, 2.0, 4.0, 8.0] {
            let g = gelu_bf16(x);
            assert!((g - x).abs() / x < 0.2, "gelu({x}) = {g}");
            assert!(g <= x);
        }
    }

    #[test]
    fn gelu_shape() {
        // GELU is not globally monotone: it dips to ≈ −0.17 near
        // x ≈ −0.75 and is monotone for x ≥ 0.
        let mut min = f32::INFINITY;
        for i in 0..200 {
            let x = -5.0 + 0.05 * i as f32;
            let g = gelu_bf16(x);
            if x < 0.0 {
                assert!((-0.2..=0.0).contains(&g), "gelu({x}) = {g}");
            }
            min = min.min(g);
        }
        assert!(min < -0.15, "dip missing: min {min}");
        let mut prev = f32::NEG_INFINITY;
        for i in 0..100 {
            let x = 0.05 * i as f32;
            let g = gelu_bf16(x);
            assert!(g >= prev - 1e-6, "non-monotone at {x} (positive side)");
            prev = g;
        }
    }

    #[test]
    fn gelu_prime_zero_where_saturated() {
        assert_eq!(gelu_prime_bf16(-8.0), 0.0);
        assert!(gelu_prime_bf16(0.0) > 0.4);
        assert!((gelu_prime_bf16(8.0) - 1.0).abs() < 0.05);
    }
}
