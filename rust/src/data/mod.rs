//! Tensor and symbol-stream generators — the substitute for the
//! paper's Gemma 2B SFT tensors (DESIGN.md §2).
//!
//! Two sources:
//! * [`TensorGen`] — synthesizes f32 tensors with trained-LLM
//!   statistics (heavy-tailed tokens, saturating GeGLU) and quantizes
//!   them with the block-32 e4m3 quantizer.  This reproduces the
//!   paper's two PMF families: smooth two-sided (FFN1 activations,
//!   weights, weight grads) and zero-spiked (FFN2 activations,
//!   activation grads).
//! * [`calibrate_generator`] — tunes the generator knob so the e4m3
//!   symbol entropy hits a target (the paper reports 6.69 bits for
//!   FFN1 and 6.11 for FFN2), giving controlled sweeps for the benches.
//!
//! Also here: the shard model (`ShardSet`, the paper's 18 layers × 64
//! shards averaging) and a small trace save/load format.

pub mod gelu;
pub mod shards;
pub mod trace;

use crate::formats::{BlockQuantizer, Variant, BLOCK};
use crate::stats::{Histogram, Pmf};
use crate::util::rng::Rng;

/// The tensor families the paper analyzes (§3).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TensorKind {
    /// FFN1 activation: pre-nonlinearity projection output — smooth.
    Ffn1Act,
    /// FFN2 activation: post-GeGLU — dominant zero symbol.
    Ffn2Act,
    /// Weights — smooth, near-Gaussian.
    Weight,
    /// Weight gradient — smooth, heavier tails.
    WeightGrad,
    /// Activation gradient — zero-spiked (mirrors FFN2 act).
    ActGrad,
}

impl TensorKind {
    pub fn name(&self) -> &'static str {
        match self {
            TensorKind::Ffn1Act => "ffn1_act",
            TensorKind::Ffn2Act => "ffn2_act",
            TensorKind::Weight => "weight",
            TensorKind::WeightGrad => "wgrad",
            TensorKind::ActGrad => "agrad",
        }
    }

    pub fn parse(s: &str) -> Option<TensorKind> {
        Some(match s {
            "ffn1_act" => TensorKind::Ffn1Act,
            "ffn2_act" => TensorKind::Ffn2Act,
            "weight" => TensorKind::Weight,
            "wgrad" => TensorKind::WeightGrad,
            "agrad" => TensorKind::ActGrad,
            _ => return None,
        })
    }

    pub fn all() -> [TensorKind; 5] {
        [
            TensorKind::Ffn1Act,
            TensorKind::Ffn2Act,
            TensorKind::Weight,
            TensorKind::WeightGrad,
            TensorKind::ActGrad,
        ]
    }
}

/// Synthetic tensor generator with trained-LLM statistics.
#[derive(Clone, Debug)]
pub struct TensorGen {
    pub kind: TensorKind,
    /// Main shape knob: lognormal σ of the per-row scale for smooth
    /// kinds; GeGLU gate gain for spiked kinds.  Larger ⇒ heavier
    /// tails / bigger zero spike.
    pub knob: f64,
    quant: BlockQuantizer,
}

impl TensorGen {
    pub fn new(kind: TensorKind, variant: Variant) -> Self {
        let knob = match kind {
            TensorKind::Ffn1Act => 0.55,
            TensorKind::Ffn2Act => 2.5,
            TensorKind::Weight => 0.3,
            TensorKind::WeightGrad => 0.6,
            TensorKind::ActGrad => 2.2,
        };
        TensorGen { kind, knob, quant: BlockQuantizer::new(variant) }
    }

    pub fn with_knob(mut self, knob: f64) -> Self {
        self.knob = knob;
        self
    }

    /// Generate `n` f32 values (`n` multiple of [`BLOCK`]).
    pub fn generate(&self, rng: &mut Rng, n: usize) -> Vec<f32> {
        assert!(n % BLOCK == 0);
        let mut out = vec![0f32; n];
        match self.kind {
            TensorKind::Weight
            | TensorKind::Ffn1Act
            | TensorKind::WeightGrad => {
                // Gaussian scale mixture at *element* level (a per-row
                // scale would be cancelled exactly by the per-block
                // absmax): v = z·exp(σw) gives Student-t-like tails
                // within each block, raising the e4m3 symbol entropy
                // above the plain-Gaussian 6.43 bits toward the paper's
                // 6.69.
                for v in out.iter_mut() {
                    let s = rng.lognormal(0.0, self.knob);
                    *v = (rng.normal() * s) as f32;
                }
            }
            TensorKind::Ffn2Act | TensorKind::ActGrad => {
                // Zero spike + smooth body (paper Fig. 4): an element is
                // exactly zero wherever the bf16 GELU saturates on its
                // gate pre-activation (gate ~ N(0, knob); larger knob ⇒
                // more saturation ⇒ bigger spike); non-saturated
                // elements follow the same scale-mixture family as FFN1
                // activations.  Modelling the non-zero body with the
                // FFN1 texture (rather than the raw gelu·up product)
                // matches the paper's sorted-PMF shape: one dominant
                // zero symbol over an FFN1-like decay.
                let zero_fn: fn(f32) -> f32 = match self.kind {
                    TensorKind::ActGrad => gelu::gelu_prime_bf16,
                    _ => gelu::gelu_bf16,
                };
                for v in out.iter_mut() {
                    let gate = (rng.normal() * self.knob) as f32;
                    if zero_fn(gate) == 0.0 {
                        *v = 0.0;
                    } else {
                        let s = rng.lognormal(0.0, 0.5);
                        *v = (rng.normal() * s) as f32;
                    }
                }
            }
        }
        out
    }

    /// Generate and quantize to e4m3 symbols.
    pub fn symbols(&self, rng: &mut Rng, n: usize) -> Vec<u8> {
        self.quant.quantize(&self.generate(rng, n)).symbols
    }

    /// PMF of a fresh sample of `n` symbols.
    pub fn sample_pmf(&self, rng: &mut Rng, n: usize) -> Pmf {
        Histogram::from_symbols(&self.symbols(rng, n)).pmf()
    }
}

/// Binary-search the generator knob until the symbol entropy is within
/// `tol` bits of `target` (paper: FFN1 → 6.69, FFN2 → 6.11).
/// Returns the calibrated generator and the achieved entropy.
pub fn calibrate_generator(
    kind: TensorKind,
    target_entropy: f64,
    seed: u64,
    tol: f64,
) -> (TensorGen, f64) {
    let sample = 256 * 1024;
    let measure = |knob: f64| -> f64 {
        let gen = TensorGen::new(kind, Variant::ExmY).with_knob(knob);
        let mut rng = Rng::new(seed);
        gen.sample_pmf(&mut rng, sample).entropy()
    };
    // Entropy is monotone in the knob per kind: heavier tails raise
    // entropy for smooth kinds; a stronger gate gain *lowers* it for
    // spiked kinds (more zeros).
    let increasing = !matches!(kind, TensorKind::Ffn2Act | TensorKind::ActGrad);
    let (mut lo, mut hi) = match kind {
        TensorKind::Ffn2Act | TensorKind::ActGrad => (0.5, 8.0),
        _ => (0.01, 2.5),
    };
    let mut best = (f64::INFINITY, lo);
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        let h = measure(mid);
        if (h - target_entropy).abs() < best.0 {
            best = ((h - target_entropy).abs(), mid);
        }
        if (h - target_entropy).abs() < tol {
            break;
        }
        let too_low = h < target_entropy;
        if too_low == increasing {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let gen = TensorGen::new(kind, Variant::ExmY).with_knob(best.1);
    let achieved = measure(best.1);
    (gen, achieved)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entropy_of(kind: TensorKind, knob: Option<f64>, seed: u64) -> (f64, f64) {
        let mut gen = TensorGen::new(kind, Variant::ExmY);
        if let Some(k) = knob {
            gen = gen.with_knob(k);
        }
        let mut rng = Rng::new(seed);
        let pmf = gen.sample_pmf(&mut rng, 128 * 1024);
        (pmf.entropy(), pmf.p[0])
    }

    #[test]
    fn smooth_kinds_have_no_zero_spike() {
        for kind in [TensorKind::Ffn1Act, TensorKind::Weight, TensorKind::WeightGrad]
        {
            let (h, p0) = entropy_of(kind, None, 1);
            assert!(p0 < 0.02, "{kind:?} p0={p0}");
            assert!((5.5..7.8).contains(&h), "{kind:?} h={h}");
        }
    }

    #[test]
    fn spiked_kinds_have_zero_spike() {
        for kind in [TensorKind::Ffn2Act, TensorKind::ActGrad] {
            let (h, p0) = entropy_of(kind, None, 2);
            assert!(p0 > 0.05, "{kind:?} p0={p0}");
            assert!(h < 7.5, "{kind:?} h={h}");
        }
    }

    #[test]
    fn knob_monotone_for_smooth() {
        let (h_lo, _) = entropy_of(TensorKind::Ffn1Act, Some(0.05), 3);
        let (h_hi, _) = entropy_of(TensorKind::Ffn1Act, Some(1.2), 3);
        assert!(h_hi > h_lo, "{h_lo} -> {h_hi}");
    }

    #[test]
    fn knob_monotone_for_spiked() {
        let (_, p0_lo) = entropy_of(TensorKind::Ffn2Act, Some(1.0), 4);
        let (_, p0_hi) = entropy_of(TensorKind::Ffn2Act, Some(4.0), 4);
        assert!(p0_hi > p0_lo, "{p0_lo} -> {p0_hi}");
    }

    #[test]
    fn calibrate_hits_paper_ffn1_entropy() {
        let (_, h) = calibrate_generator(TensorKind::Ffn1Act, 6.69, 7, 0.02);
        assert!((h - 6.69).abs() < 0.05, "calibrated to {h}");
    }

    #[test]
    fn calibrate_hits_paper_ffn2_entropy() {
        let (gen, h) = calibrate_generator(TensorKind::Ffn2Act, 6.11, 8, 0.02);
        assert!((h - 6.11).abs() < 0.08, "calibrated to {h}");
        // And the calibrated distribution keeps the zero spike.
        let mut rng = Rng::new(9);
        let pmf = gen.sample_pmf(&mut rng, 64 * 1024);
        let sorted = pmf.sorted_desc();
        assert_eq!(sorted[0], pmf.p[0], "zero must be the modal symbol");
    }

    #[test]
    fn deterministic_given_seed() {
        let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
        let a = gen.symbols(&mut Rng::new(42), 32 * BLOCK);
        let b = gen.symbols(&mut Rng::new(42), 32 * BLOCK);
        assert_eq!(a, b);
    }

    #[test]
    fn kind_name_roundtrip() {
        for kind in TensorKind::all() {
            assert_eq!(TensorKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(TensorKind::parse("nope"), None);
    }

    #[test]
    fn modal_symbols_are_midrange_magnitudes() {
        // Paper Fig. 7: the most frequent symbols sit in the mid-
        // magnitude e4m3 region (their examples: 113, 241, 234, 106 —
        // exponent fields 13–14), not at 0 or at the top code.
        let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
        let mut rng = Rng::new(11);
        let pmf = gen.sample_pmf(&mut rng, 256 * 1024);
        let rank = pmf.rank_order();
        let top = rank[0] & 0x7F;
        let exp_field = (top >> 3) & 0xF;
        assert!(
            (11..=15).contains(&exp_field),
            "top symbol {} (exp {})",
            rank[0],
            exp_field
        );
    }
}
