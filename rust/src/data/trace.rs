//! Symbol-trace persistence: raw symbol bytes + a JSON sidecar with
//! provenance (kind, seed, entropy).  Lets the benches and the CLI
//! re-use harvested tensors without re-running the PJRT runtime.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::stats::Histogram;
use crate::util::json::Json;

/// A stored symbol trace.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub name: String,
    pub symbols: Vec<u8>,
    /// Free-form provenance fields.
    pub meta: Json,
}

impl Trace {
    pub fn new(name: &str, symbols: Vec<u8>) -> Self {
        Trace { name: name.to_string(), symbols, meta: Json::obj() }
    }

    pub fn with_meta(mut self, key: &str, value: impl Into<Json>) -> Self {
        self.meta = self.meta.set(key, value);
        self
    }

    fn paths(dir: &Path, name: &str) -> (PathBuf, PathBuf) {
        (
            dir.join(format!("{name}.syms")),
            dir.join(format!("{name}.json")),
        )
    }

    /// Write `<dir>/<name>.syms` + `<dir>/<name>.json`.
    pub fn save(&self, dir: &Path) -> io::Result<()> {
        fs::create_dir_all(dir)?;
        let (sym_path, meta_path) = Self::paths(dir, &self.name);
        fs::write(&sym_path, &self.symbols)?;
        let hist = if self.symbols.is_empty() {
            None
        } else {
            Some(Histogram::from_symbols(&self.symbols))
        };
        let mut meta = self
            .meta
            .clone()
            .set("name", self.name.as_str())
            .set("num_symbols", self.symbols.len());
        if let Some(h) = hist {
            meta = meta.set("entropy_bits", h.pmf().entropy());
        }
        fs::write(&meta_path, meta.to_string_pretty())?;
        Ok(())
    }

    /// Load a trace saved by [`Trace::save`].
    pub fn load(dir: &Path, name: &str) -> io::Result<Trace> {
        let (sym_path, meta_path) = Self::paths(dir, name);
        let symbols = fs::read(&sym_path)?;
        let meta_text = fs::read_to_string(&meta_path)?;
        let meta = Json::parse(&meta_text).map_err(|e| {
            io::Error::new(io::ErrorKind::InvalidData, e.to_string())
        })?;
        let declared = meta.get("num_symbols").and_then(Json::as_usize);
        if declared != Some(symbols.len()) {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!(
                    "sidecar declares {declared:?} symbols, file has {}",
                    symbols.len()
                ),
            ));
        }
        Ok(Trace { name: name.to_string(), symbols, meta })
    }

    /// All trace names present in `dir`.
    pub fn list(dir: &Path) -> io::Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in fs::read_dir(dir)? {
            let path = entry?.path();
            if path.extension().map(|e| e == "syms").unwrap_or(false) {
                if let Some(stem) = path.file_stem().and_then(|s| s.to_str()) {
                    names.push(stem.to_string());
                }
            }
        }
        names.sort();
        Ok(names)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "qlc-trace-test-{tag}-{}",
            std::process::id()
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let mut rng = Rng::new(1);
        let mut symbols = vec![0u8; 4096];
        rng.fill_bytes(&mut symbols);
        let trace = Trace::new("ffn1_act", symbols.clone())
            .with_meta("kind", "ffn1_act")
            .with_meta("seed", 1usize);
        trace.save(&dir).unwrap();
        let back = Trace::load(&dir, "ffn1_act").unwrap();
        assert_eq!(back.symbols, symbols);
        assert_eq!(back.meta.get("kind").unwrap().as_str(), Some("ffn1_act"));
        assert!(back.meta.get("entropy_bits").unwrap().as_f64().unwrap() > 0.0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn list_traces() {
        let dir = tmp_dir("list");
        Trace::new("b", vec![1, 2]).save(&dir).unwrap();
        Trace::new("a", vec![3]).save(&dir).unwrap();
        assert_eq!(Trace::list(&dir).unwrap(), vec!["a", "b"]);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn detects_size_mismatch() {
        let dir = tmp_dir("mismatch");
        Trace::new("t", vec![1, 2, 3]).save(&dir).unwrap();
        fs::write(dir.join("t.syms"), [1u8, 2]).unwrap();
        assert!(Trace::load(&dir, "t").is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_trace_errors() {
        let dir = tmp_dir("missing");
        fs::create_dir_all(&dir).unwrap();
        assert!(Trace::load(&dir, "nope").is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn empty_trace_roundtrips() {
        let dir = tmp_dir("empty");
        Trace::new("e", vec![]).save(&dir).unwrap();
        let back = Trace::load(&dir, "e").unwrap();
        assert!(back.symbols.is_empty());
        fs::remove_dir_all(&dir).unwrap();
    }
}
