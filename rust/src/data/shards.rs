//! The paper's shard model: each tensor type exists as
//! `layers × shards-per-layer` shards (18 × 64 = 1152 for Gemma 2B on
//! 64 TPUs); PMFs are averaged over all shards (paper §4: "averaged
//! over all shards").

use super::{TensorGen, TensorKind};
use crate::formats::Variant;
use crate::stats::{average_pmfs, Histogram, Pmf};
use crate::util::rng::Rng;

/// Shard topology.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    pub layers: usize,
    pub shards_per_layer: usize,
    /// Symbols sampled per shard.
    pub symbols_per_shard: usize,
}

impl ShardConfig {
    /// The paper's topology, scaled down by `scale` (1 = full 18×64).
    pub fn paper_scaled(scale: usize) -> Self {
        ShardConfig {
            layers: (18 / scale).max(1),
            shards_per_layer: (64 / scale).max(1),
            symbols_per_shard: 32 * 1024,
        }
    }

    pub fn total_shards(&self) -> usize {
        self.layers * self.shards_per_layer
    }
}

/// All shards of one tensor type, with per-shard histograms.
#[derive(Clone, Debug)]
pub struct ShardSet {
    pub kind: TensorKind,
    pub config: ShardConfig,
    pub histograms: Vec<Histogram>,
}

impl ShardSet {
    /// Generate every shard (deterministic per `seed`; each shard gets
    /// an independent RNG stream, and a mild per-layer scale drift so
    /// shards are similar-but-not-identical, as across a real model).
    pub fn generate(
        kind: TensorKind,
        config: ShardConfig,
        knob: f64,
        seed: u64,
    ) -> Self {
        let mut root = Rng::new(seed);
        let mut histograms = Vec::with_capacity(config.total_shards());
        for layer in 0..config.layers {
            // Per-layer drift of the statistics knob (±15%).
            let drift = 1.0 + 0.15 * (root.uniform() * 2.0 - 1.0);
            for shard in 0..config.shards_per_layer {
                let mut rng =
                    root.fork((layer * config.shards_per_layer + shard) as u64);
                let gen = TensorGen::new(kind, Variant::ExmY)
                    .with_knob(knob * drift);
                let symbols = gen.symbols(&mut rng, config.symbols_per_shard);
                histograms.push(Histogram::from_symbols(&symbols));
            }
        }
        ShardSet { kind, config, histograms }
    }

    /// The paper's averaged PMF.
    pub fn average_pmf(&self) -> Pmf {
        let pmfs: Vec<Pmf> = self.histograms.iter().map(|h| h.pmf()).collect();
        average_pmfs(&pmfs)
    }

    /// Pooled histogram (total counts across shards).
    pub fn pooled(&self) -> Histogram {
        let mut h = Histogram::new();
        for shard in &self.histograms {
            h.merge(shard);
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> ShardConfig {
        ShardConfig { layers: 3, shards_per_layer: 4, symbols_per_shard: 8192 }
    }

    #[test]
    fn shard_count() {
        let set = ShardSet::generate(TensorKind::Ffn1Act, small(), 0.55, 1);
        assert_eq!(set.histograms.len(), 12);
        assert_eq!(set.config.total_shards(), 12);
    }

    #[test]
    fn paper_scaled_topology() {
        let full = ShardConfig::paper_scaled(1);
        assert_eq!(full.layers, 18);
        assert_eq!(full.shards_per_layer, 64);
        let sixth = ShardConfig::paper_scaled(6);
        assert_eq!(sixth.layers, 3);
        assert_eq!(sixth.total_shards(), 30);
    }

    #[test]
    fn deterministic() {
        let a = ShardSet::generate(TensorKind::Weight, small(), 0.3, 9);
        let b = ShardSet::generate(TensorKind::Weight, small(), 0.3, 9);
        assert_eq!(a.histograms[5], b.histograms[5]);
    }

    #[test]
    fn shards_differ_from_each_other() {
        let set = ShardSet::generate(TensorKind::Ffn1Act, small(), 0.55, 2);
        assert_ne!(set.histograms[0], set.histograms[1]);
    }

    #[test]
    fn average_pmf_close_to_pooled_pmf() {
        // Equal-sized shards ⇒ the two aggregations agree.
        let set = ShardSet::generate(TensorKind::Ffn1Act, small(), 0.55, 3);
        let avg = set.average_pmf();
        let pooled = set.pooled().pmf();
        assert!(avg.tv_distance(&pooled) < 1e-9);
    }

    #[test]
    fn averaged_entropy_in_expected_band() {
        let set = ShardSet::generate(TensorKind::Ffn2Act, small(), 2.5, 4);
        let h = set.average_pmf().entropy();
        assert!((4.5..7.6).contains(&h), "h={h}");
    }
}
