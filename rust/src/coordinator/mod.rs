//! Threaded leader/worker compression pipeline — the L3 service that
//! puts the codec on a request path: tensors arrive as symbol streams,
//! the leader places *descriptors* (byte ranges into a shared stream,
//! or shard slots of a [`frame::ShardManifest`]) on a worker pool with
//! bounded queues (backpressure), and re-assembles the results in
//! order.
//!
//! Workers never receive copied payload bytes: a job is `(seq, range)`
//! into an `Arc`-shared stream, so the only per-job allocation is the
//! compressed output.  In shard mode each worker emits one QLS1 shard
//! body and the leader assembles the manifest — the sharded analogue
//! of the frame path, feeding placement-aware consumers (one shard per
//! worker/NUMA node) without re-serializing the codec tables per
//! shard.
//!
//! The paper's contribution is the codec itself, so this coordinator
//! is deliberately thin but real: ordered delivery, worker-count
//! scaling, per-job metrics, and failure containment are all exercised
//! by the tests and the `pipeline` benches.

pub mod metrics;

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Instant;

use crate::codecs::frame::{self, FrameOptions, ShardManifest};
use crate::codecs::{chunk_spans, CodecRegistry};
use crate::obs;
use crate::stats::Histogram;
use metrics::PipelineMetrics;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub workers: usize,
    /// Symbols per compression job.
    pub chunk_size: usize,
    /// Bounded queue depth (backpressure).
    pub queue_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { workers: 4, chunk_size: 64 * 1024, queue_depth: 8 }
    }
}

/// A placement descriptor: which slice of the shared stream to
/// compress, and into which container.
struct Job {
    seq: usize,
    stream: Arc<Vec<u8>>,
    start: usize,
    len: usize,
    /// `Some(index)` → emit a QLS1 shard body; `None` → a QLF2 frame.
    shard: Option<u32>,
}

struct Done {
    seq: usize,
    bytes: Vec<u8>,
    n_symbols: usize,
    codec_seconds: f64,
}

/// A running compression pipeline bound to one codec spec.
pub struct Pipeline {
    tx: Option<SyncSender<Job>>,
    rx_done: Receiver<Done>,
    handles: Vec<JoinHandle<()>>,
    /// Private metric registry — one per pipeline, so concurrent
    /// pipelines (and tests) never see each other's counts.
    /// [`Pipeline::metrics`] derives [`PipelineMetrics`] from it.
    obs: Arc<obs::Registry>,
    chunk_size: usize,
    /// The codec's wire identity (tag + table header), captured from
    /// the first worker's resolve — all the leader needs to assemble a
    /// [`ShardManifest`] without fitting its own tables.
    wire_tag: u8,
    wire_header: Vec<u8>,
}

impl Pipeline {
    /// Spawn the worker pool. `codec` and `calibration` follow
    /// [`CodecRegistry::resolve`].
    pub fn new(
        config: PipelineConfig,
        codec: &str,
        calibration: &Histogram,
    ) -> Result<Pipeline, String> {
        if config.workers == 0 {
            return Err("pipeline requires at least one worker".into());
        }
        if config.chunk_size == 0 {
            return Err("pipeline chunk size must be non-zero".into());
        }
        if config.queue_depth == 0 {
            return Err("pipeline queue depth must be non-zero".into());
        }
        let (tx, rx) = sync_channel::<Job>(config.queue_depth);
        let (tx_done, rx_done) = sync_channel::<Done>(config.queue_depth * 2);
        let rx = Arc::new(Mutex::new(rx));
        let obs_reg = Arc::new(obs::Registry::new());
        let mut wire_identity: Option<(u8, Vec<u8>)> = None;

        let mut handles = Vec::with_capacity(config.workers);
        for _ in 0..config.workers {
            // Each worker owns its own codec tables (no sharing/locking
            // on the hot path) and emits serial single-frame output —
            // the pool, not the frame layer, is the parallelism here.
            let handle = CodecRegistry::global().resolve(codec, calibration)?;
            if wire_identity.is_none() {
                wire_identity = Some((
                    handle.wire_tag(),
                    handle.wire_header().to_vec(),
                ));
            }
            let rx = rx.clone();
            let tx_done = tx_done.clone();
            let jobs_total = obs_reg.counter("pipeline_jobs_total");
            let shards_total = obs_reg.counter("pipeline_shards_total");
            let input_bytes = obs_reg.counter("pipeline_input_bytes_total");
            let output_bytes = obs_reg.counter("pipeline_output_bytes_total");
            let codec_ns = obs_reg.hist("pipeline_codec_ns");
            handles.push(thread::spawn(move || loop {
                let job = {
                    // lint: infallible(worker-pool mutex: poisoned only
                    // if a sibling worker already panicked, and then
                    // this thread cannot make progress anyway)
                    let guard = rx.lock().expect("job queue");
                    guard.recv()
                };
                let Ok(job) = job else { break };
                let slice = &job.stream[job.start..job.start + job.len];
                let _sp = obs::span("pipeline.job")
                    .arg("seq", job.seq)
                    .arg("symbols", job.len);
                let t0 = Instant::now();
                // Job slices are always far below the QLF2 chunk cap,
                // so the checked writer cannot fail here.
                let bytes = match job.shard {
                    None => frame::compress_with(
                        &handle,
                        slice,
                        &FrameOptions::serial(),
                    )
                    // lint: infallible(job slices are chunk_size-bounded,
                    // far under the QLF2 chunk cap)
                    .expect("pipeline chunks stay under the QLF2 chunk cap"),
                    Some(index) => frame::compress_shard(
                        &handle,
                        index,
                        slice,
                        &FrameOptions::serial(),
                    )
                    // lint: infallible(job slices are chunk_size-bounded,
                    // far under the QLF2 chunk cap)
                    .expect("pipeline shards stay under the QLF2 chunk cap"),
                };
                let dt = t0.elapsed().as_secs_f64();
                jobs_total.inc();
                if job.shard.is_some() {
                    shards_total.inc();
                }
                input_bytes.add(job.len as u64);
                output_bytes.add(bytes.len() as u64);
                codec_ns.record((dt * 1e9) as u64);
                if tx_done
                    .send(Done {
                        seq: job.seq,
                        bytes,
                        n_symbols: job.len,
                        codec_seconds: dt,
                    })
                    .is_err()
                {
                    break;
                }
            }));
        }
        let (wire_tag, wire_header) = wire_identity
            .ok_or("pipeline: no worker resolved a codec identity")?;
        Ok(Pipeline {
            tx: Some(tx),
            rx_done,
            handles,
            obs: obs_reg,
            chunk_size: config.chunk_size,
            wire_tag,
            wire_header,
        })
    }

    /// Fan descriptors out to the pool and re-assemble results in
    /// sequence order.  `descs` are `(start, len, shard)` ranges into
    /// `stream`.
    fn run_jobs(
        &self,
        stream: Arc<Vec<u8>>,
        descs: Vec<(usize, usize, Option<u32>)>,
    ) -> Result<Vec<Vec<u8>>, String> {
        // A shut-down pipeline is a caller-reachable state (shutdown()
        // is public), so this is an error, not a panic.
        let tx = self
            .tx
            .as_ref()
            .ok_or("pipeline already shut down; create a new Pipeline")?;
        let total = descs.len();
        let mut results: Vec<Option<Vec<u8>>> = vec![None; total];
        let mut submitted = 0usize;
        let mut received = 0usize;
        // Interleave submit/drain so bounded queues never deadlock.
        while received < total {
            while submitted < total {
                let (start, len, shard) = descs[submitted];
                let job = Job {
                    seq: submitted,
                    stream: stream.clone(),
                    start,
                    len,
                    shard,
                };
                match tx.try_send(job) {
                    Ok(()) => submitted += 1,
                    Err(std::sync::mpsc::TrySendError::Full(_)) => break,
                    Err(e) => {
                        return Err(format!(
                            "pipeline send failed (worker pool died): {e}"
                        ))
                    }
                }
            }
            let done = self.rx_done.recv().map_err(|_| {
                "pipeline drain failed: worker pool disconnected"
                    .to_string()
            })?;
            results[done.seq] = Some(done.bytes);
            let _ = (done.n_symbols, done.codec_seconds);
            received += 1;
        }
        results
            .into_iter()
            .map(|r| r.ok_or_else(|| "pipeline lost a chunk".to_string()))
            .collect()
    }

    /// Compress a full stream: chunk, fan out, re-assemble in order.
    /// Returns the ordered frames.
    pub fn compress_stream(
        &self,
        symbols: &[u8],
    ) -> Result<Vec<Vec<u8>>, String> {
        let stream = Arc::new(symbols.to_vec());
        let descs = chunk_spans(symbols.len(), self.chunk_size)
            .into_iter()
            .map(|(a, b)| (a, b - a, None))
            .collect();
        self.run_jobs(stream, descs)
    }

    /// Compress a stream into `n_shards` placement units: each worker
    /// job is one shard descriptor, the leader assembles the shared
    /// [`ShardManifest`].  Output is identical to
    /// [`frame::compress_sharded`] with the same codec — worker count
    /// never changes bytes.
    pub fn compress_sharded(
        &self,
        symbols: &[u8],
        n_shards: usize,
    ) -> Result<(ShardManifest, Vec<Vec<u8>>), String> {
        let plan = frame::shard_plan(symbols.len(), n_shards);
        let stream = Arc::new(symbols.to_vec());
        let descs = plan
            .iter()
            .map(|d| (d.start, d.n_symbols, Some(d.index as u32)))
            .collect();
        let bodies = self.run_jobs(stream, descs)?;
        let manifest = ShardManifest::new(
            self.wire_tag,
            self.wire_header.clone(),
            plan.iter().map(|d| d.n_symbols as u64).collect(),
        );
        Ok((manifest, bodies))
    }

    /// Convenience: compress and decompress back, returning the
    /// reconstructed stream (used by integration tests).
    pub fn roundtrip(&self, symbols: &[u8]) -> Result<Vec<u8>, String> {
        let mut out = Vec::with_capacity(symbols.len());
        for f in self.compress_stream(symbols)? {
            out.extend(frame::decompress(&f)?);
        }
        Ok(out)
    }

    /// Report-facing metrics, derived from the pipeline's private
    /// observability registry (lock-free atomics on the worker path —
    /// the old bespoke `Mutex<PipelineMetrics>` is gone).
    pub fn metrics(&self) -> PipelineMetrics {
        let snap = self.obs.snapshot();
        let c = |key: &str| snap.counters.get(key).copied().unwrap_or(0);
        let codec_ns = snap
            .hists
            .get("pipeline_codec_ns")
            .map(|h| h.sum)
            .unwrap_or(0);
        PipelineMetrics {
            jobs: c("pipeline_jobs_total"),
            shards: c("pipeline_shards_total"),
            input_bytes: c("pipeline_input_bytes_total"),
            output_bytes: c("pipeline_output_bytes_total"),
            codec_seconds: codec_ns as f64 / 1e9,
        }
    }

    /// Raw snapshot of the pipeline's registry (counters plus the
    /// per-job codec latency histogram), for exporters.
    pub fn obs_snapshot(&self) -> obs::Snapshot {
        self.obs.snapshot()
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(&mut self) {
        self.tx.take(); // closes the job queue
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorGen, TensorKind};
    use crate::formats::Variant;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> (Vec<u8>, Histogram) {
        let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
        let mut rng = Rng::new(seed);
        let symbols = gen.symbols(&mut rng, n);
        let hist = Histogram::from_symbols(&symbols);
        (symbols, hist)
    }

    #[test]
    fn ordered_roundtrip() {
        let (symbols, hist) = sample(512 * 1024, 1);
        let cfg = PipelineConfig { workers: 4, chunk_size: 10_000, queue_depth: 4 };
        let pipe = Pipeline::new(cfg, "qlc", &hist).unwrap();
        assert_eq!(pipe.roundtrip(&symbols).unwrap(), symbols);
    }

    #[test]
    fn single_worker_matches_multi() {
        let (symbols, hist) = sample(128 * 1024, 2);
        let one = Pipeline::new(
            PipelineConfig { workers: 1, chunk_size: 8192, queue_depth: 2 },
            "huffman",
            &hist,
        )
        .unwrap();
        let many = Pipeline::new(
            PipelineConfig { workers: 8, chunk_size: 8192, queue_depth: 8 },
            "huffman",
            &hist,
        )
        .unwrap();
        assert_eq!(
            one.compress_stream(&symbols).unwrap(),
            many.compress_stream(&symbols).unwrap(),
            "frame content must not depend on worker count"
        );
    }

    #[test]
    fn sharded_pipeline_matches_direct_encode() {
        let (symbols, hist) = sample(256 * 1024, 7);
        let pipe = Pipeline::new(
            PipelineConfig { workers: 3, chunk_size: 4096, queue_depth: 4 },
            "qlc",
            &hist,
        )
        .unwrap();
        let (manifest, shards) = pipe.compress_sharded(&symbols, 5).unwrap();
        // Worker pool and direct scoped-thread encode agree byte for
        // byte (and so does the manifest).
        let handle =
            CodecRegistry::global().resolve("qlc", &hist).unwrap();
        let (direct_manifest, direct_shards) = frame::compress_sharded(
            &handle,
            &symbols,
            5,
            &FrameOptions::serial(),
        )
        .unwrap();
        assert_eq!(manifest, direct_manifest);
        assert_eq!(shards, direct_shards);
        // And the sharded set reassembles.
        let back = frame::decompress_sharded(
            &manifest,
            &shards,
            &FrameOptions::default(),
        )
        .unwrap();
        assert_eq!(back, symbols);
        let m = pipe.metrics();
        assert_eq!(m.shards, 5);
        assert_eq!(m.jobs, 5);
    }

    #[test]
    fn metrics_accumulate() {
        let (symbols, hist) = sample(64 * 1024, 3);
        let pipe = Pipeline::new(
            PipelineConfig { workers: 2, chunk_size: 4096, queue_depth: 4 },
            "qlc-t1",
            &hist,
        )
        .unwrap();
        let frames = pipe.compress_stream(&symbols).unwrap();
        let m = pipe.metrics();
        assert_eq!(m.jobs as usize, frames.len());
        assert_eq!(m.input_bytes as usize, symbols.len());
        assert_eq!(m.shards, 0, "frame jobs are not shard jobs");
        assert!(m.output_bytes > 0);
        assert!(m.codec_seconds > 0.0);
        assert!(
            m.compressibility().unwrap() > 0.0,
            "skewed data must compress"
        );
        // The registry view agrees with the derived struct: one
        // latency sample per job, on this pipeline's private registry
        // (concurrent tests must not bleed into these counts).
        let snap = pipe.obs_snapshot();
        let lat = snap.hists.get("pipeline_codec_ns").unwrap();
        assert_eq!(lat.count, m.jobs);
        assert_eq!(
            snap.counters.get("pipeline_input_bytes_total").copied(),
            Some(symbols.len() as u64)
        );
    }

    #[test]
    fn tiny_chunks_and_empty_stream() {
        let (_, hist) = sample(1024, 4);
        let pipe = Pipeline::new(
            PipelineConfig { workers: 3, chunk_size: 1, queue_depth: 2 },
            "raw",
            &hist,
        )
        .unwrap();
        assert_eq!(pipe.roundtrip(&[]).unwrap(), Vec::<u8>::new());
        let data = vec![7u8, 8, 9];
        assert_eq!(pipe.roundtrip(&data).unwrap(), data);
    }

    #[test]
    fn more_jobs_than_queue_depth() {
        let (symbols, hist) = sample(256 * 1024, 5);
        let pipe = Pipeline::new(
            PipelineConfig { workers: 2, chunk_size: 1024, queue_depth: 2 },
            "qlc",
            &hist,
        )
        .unwrap();
        // 256 jobs through a depth-2 queue: backpressure must not
        // deadlock or reorder.
        assert_eq!(pipe.roundtrip(&symbols).unwrap(), symbols);
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (_, hist) = sample(1024, 6);
        let mut pipe =
            Pipeline::new(PipelineConfig::default(), "raw", &hist).unwrap();
        pipe.shutdown();
        pipe.shutdown();
    }

    /// Regression: compressing through a shut-down pipeline used to
    /// panic on an `expect` inside `run_jobs`; `shutdown()` is public,
    /// so that state is caller-reachable and must be an `Err`.
    #[test]
    fn compress_after_shutdown_is_an_error_not_a_panic() {
        let (symbols, hist) = sample(4096, 9);
        let mut pipe =
            Pipeline::new(PipelineConfig::default(), "raw", &hist).unwrap();
        pipe.shutdown();
        let err = pipe.compress_stream(&symbols).unwrap_err();
        assert!(err.contains("shut down"), "{err}");
        assert!(pipe.compress_sharded(&symbols, 2).is_err());
        assert!(pipe.roundtrip(&symbols).is_err());
        // Metrics stay readable after shutdown.
        assert_eq!(pipe.metrics().jobs, 0);
    }

    #[test]
    fn unknown_codec_fails_fast() {
        let (_, hist) = sample(1024, 7);
        assert!(Pipeline::new(PipelineConfig::default(), "lzma", &hist)
            .is_err());
    }

    #[test]
    fn malformed_config_is_an_error_not_a_panic() {
        let (_, hist) = sample(1024, 8);
        for cfg in [
            PipelineConfig { workers: 0, ..Default::default() },
            PipelineConfig { chunk_size: 0, ..Default::default() },
            PipelineConfig { queue_depth: 0, ..Default::default() },
        ] {
            assert!(Pipeline::new(cfg, "raw", &hist).is_err());
        }
    }
}
