//! Threaded leader/worker compression pipeline — the L3 service that
//! puts the codec on a request path: tensors arrive as symbol streams,
//! are chunked, compressed in parallel by a worker pool with bounded
//! queues (backpressure), and re-assembled in order by the leader.
//!
//! The paper's contribution is the codec itself, so this coordinator is
//! deliberately thin but real: ordered delivery, worker-count scaling,
//! per-job metrics, and failure containment are all exercised by the
//! tests and the `pipeline` benches.

pub mod metrics;

use std::sync::mpsc::{sync_channel, Receiver, SyncSender};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::Instant;

use crate::codecs::frame::{self, FrameOptions};
use crate::codecs::CodecRegistry;
use crate::stats::Histogram;
use metrics::PipelineMetrics;

/// Pipeline configuration.
#[derive(Clone, Debug)]
pub struct PipelineConfig {
    pub workers: usize,
    /// Symbols per compression job.
    pub chunk_size: usize,
    /// Bounded queue depth (backpressure).
    pub queue_depth: usize,
}

impl Default for PipelineConfig {
    fn default() -> Self {
        PipelineConfig { workers: 4, chunk_size: 64 * 1024, queue_depth: 8 }
    }
}

struct Job {
    seq: usize,
    symbols: Vec<u8>,
}

struct Done {
    seq: usize,
    frame: Vec<u8>,
    n_symbols: usize,
    codec_seconds: f64,
}

/// A running compression pipeline bound to one codec spec.
pub struct Pipeline {
    tx: Option<SyncSender<Job>>,
    rx_done: Receiver<Done>,
    handles: Vec<JoinHandle<()>>,
    metrics: Arc<Mutex<PipelineMetrics>>,
    chunk_size: usize,
}

impl Pipeline {
    /// Spawn the worker pool. `codec` and `calibration` follow
    /// [`CodecRegistry::resolve`].
    pub fn new(
        config: PipelineConfig,
        codec: &str,
        calibration: &Histogram,
    ) -> Result<Pipeline, String> {
        assert!(config.workers >= 1);
        assert!(config.chunk_size >= 1);
        let (tx, rx) = sync_channel::<Job>(config.queue_depth);
        let (tx_done, rx_done) = sync_channel::<Done>(config.queue_depth * 2);
        let rx = Arc::new(Mutex::new(rx));
        let metrics = Arc::new(Mutex::new(PipelineMetrics::default()));

        let mut handles = Vec::with_capacity(config.workers);
        for _ in 0..config.workers {
            // Each worker owns its own codec tables (no sharing/locking
            // on the hot path) and emits serial single-frame output —
            // the pool, not the frame layer, is the parallelism here.
            let handle = CodecRegistry::global().resolve(codec, calibration)?;
            let rx = rx.clone();
            let tx_done = tx_done.clone();
            let metrics = metrics.clone();
            handles.push(thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().expect("job queue");
                    guard.recv()
                };
                let Ok(job) = job else { break };
                let t0 = Instant::now();
                let frame = frame::compress_with(
                    &handle,
                    &job.symbols,
                    &FrameOptions::serial(),
                );
                let dt = t0.elapsed().as_secs_f64();
                {
                    let mut m = metrics.lock().expect("metrics");
                    m.jobs += 1;
                    m.input_bytes += job.symbols.len() as u64;
                    m.output_bytes += frame.len() as u64;
                    m.codec_seconds += dt;
                }
                if tx_done
                    .send(Done {
                        seq: job.seq,
                        frame,
                        n_symbols: job.symbols.len(),
                        codec_seconds: dt,
                    })
                    .is_err()
                {
                    break;
                }
            }));
        }
        Ok(Pipeline {
            tx: Some(tx),
            rx_done,
            handles,
            metrics,
            chunk_size: config.chunk_size,
        })
    }

    /// Compress a full stream: chunk, fan out, re-assemble in order.
    /// Returns the ordered frames.
    pub fn compress_stream(&self, symbols: &[u8]) -> Vec<Vec<u8>> {
        let tx = self.tx.as_ref().expect("pipeline already shut down");
        let chunks: Vec<&[u8]> = symbols.chunks(self.chunk_size).collect();
        let total = chunks.len();
        let mut results: Vec<Option<Vec<u8>>> = vec![None; total];
        let mut submitted = 0usize;
        let mut received = 0usize;
        // Interleave submit/drain so bounded queues never deadlock.
        while received < total {
            while submitted < total {
                let job = Job {
                    seq: submitted,
                    symbols: chunks[submitted].to_vec(),
                };
                match tx.try_send(job) {
                    Ok(()) => submitted += 1,
                    Err(std::sync::mpsc::TrySendError::Full(_)) => break,
                    Err(e) => panic!("pipeline send: {e}"),
                }
            }
            let done = self.rx_done.recv().expect("pipeline drain");
            results[done.seq] = Some(done.frame);
            let _ = (done.n_symbols, done.codec_seconds);
            received += 1;
        }
        results.into_iter().map(|r| r.expect("all chunks done")).collect()
    }

    /// Convenience: compress and decompress back, returning the
    /// reconstructed stream (used by integration tests).
    pub fn roundtrip(&self, symbols: &[u8]) -> Vec<u8> {
        self.compress_stream(symbols)
            .iter()
            .flat_map(|f| frame::decompress(f).expect("pipeline frame"))
            .collect()
    }

    pub fn metrics(&self) -> PipelineMetrics {
        self.metrics.lock().expect("metrics").clone()
    }

    /// Graceful shutdown (also runs on drop).
    pub fn shutdown(&mut self) {
        self.tx.take(); // closes the job queue
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for Pipeline {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{TensorGen, TensorKind};
    use crate::formats::Variant;
    use crate::util::rng::Rng;

    fn sample(n: usize, seed: u64) -> (Vec<u8>, Histogram) {
        let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
        let mut rng = Rng::new(seed);
        let symbols = gen.symbols(&mut rng, n);
        let hist = Histogram::from_symbols(&symbols);
        (symbols, hist)
    }

    #[test]
    fn ordered_roundtrip() {
        let (symbols, hist) = sample(512 * 1024, 1);
        let cfg = PipelineConfig { workers: 4, chunk_size: 10_000, queue_depth: 4 };
        let pipe = Pipeline::new(cfg, "qlc", &hist).unwrap();
        assert_eq!(pipe.roundtrip(&symbols), symbols);
    }

    #[test]
    fn single_worker_matches_multi() {
        let (symbols, hist) = sample(128 * 1024, 2);
        let one = Pipeline::new(
            PipelineConfig { workers: 1, chunk_size: 8192, queue_depth: 2 },
            "huffman",
            &hist,
        )
        .unwrap();
        let many = Pipeline::new(
            PipelineConfig { workers: 8, chunk_size: 8192, queue_depth: 8 },
            "huffman",
            &hist,
        )
        .unwrap();
        assert_eq!(
            one.compress_stream(&symbols),
            many.compress_stream(&symbols),
            "frame content must not depend on worker count"
        );
    }

    #[test]
    fn metrics_accumulate() {
        let (symbols, hist) = sample(64 * 1024, 3);
        let pipe = Pipeline::new(
            PipelineConfig { workers: 2, chunk_size: 4096, queue_depth: 4 },
            "qlc-t1",
            &hist,
        )
        .unwrap();
        let frames = pipe.compress_stream(&symbols);
        let m = pipe.metrics();
        assert_eq!(m.jobs as usize, frames.len());
        assert_eq!(m.input_bytes as usize, symbols.len());
        assert!(m.output_bytes > 0);
        assert!(m.codec_seconds > 0.0);
        assert!(m.compressibility() > 0.0, "skewed data must compress");
    }

    #[test]
    fn tiny_chunks_and_empty_stream() {
        let (_, hist) = sample(1024, 4);
        let pipe = Pipeline::new(
            PipelineConfig { workers: 3, chunk_size: 1, queue_depth: 2 },
            "raw",
            &hist,
        )
        .unwrap();
        assert_eq!(pipe.roundtrip(&[]), Vec::<u8>::new());
        let data = vec![7u8, 8, 9];
        assert_eq!(pipe.roundtrip(&data), data);
    }

    #[test]
    fn more_jobs_than_queue_depth() {
        let (symbols, hist) = sample(256 * 1024, 5);
        let pipe = Pipeline::new(
            PipelineConfig { workers: 2, chunk_size: 1024, queue_depth: 2 },
            "qlc",
            &hist,
        )
        .unwrap();
        // 256 jobs through a depth-2 queue: backpressure must not
        // deadlock or reorder.
        assert_eq!(pipe.roundtrip(&symbols), symbols);
    }

    #[test]
    fn shutdown_is_idempotent() {
        let (_, hist) = sample(1024, 6);
        let mut pipe =
            Pipeline::new(PipelineConfig::default(), "raw", &hist).unwrap();
        pipe.shutdown();
        pipe.shutdown();
    }

    #[test]
    fn unknown_codec_fails_fast() {
        let (_, hist) = sample(1024, 7);
        assert!(Pipeline::new(PipelineConfig::default(), "lzma", &hist)
            .is_err());
    }
}
