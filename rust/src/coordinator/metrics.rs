//! Pipeline metrics: compression ratio and throughput accounting for
//! the coordinator (and its JSON report for the CLI).

use crate::util::json::Json;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct PipelineMetrics {
    pub jobs: u64,
    /// Jobs that emitted QLS1 shard bodies (subset of `jobs`).
    pub shards: u64,
    pub input_bytes: u64,
    pub output_bytes: u64,
    /// Total codec wall time across workers (not wall-clock elapsed).
    pub codec_seconds: f64,
}

impl PipelineMetrics {
    /// Fraction of bytes removed (the paper's metric).
    pub fn compressibility(&self) -> f64 {
        if self.input_bytes == 0 {
            return 0.0;
        }
        1.0 - self.output_bytes as f64 / self.input_bytes as f64
    }

    /// Aggregate codec throughput, MB/s (1e6 bytes).
    pub fn throughput_mbps(&self) -> f64 {
        if self.codec_seconds <= 0.0 {
            return 0.0;
        }
        self.input_bytes as f64 / self.codec_seconds / 1e6
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("jobs", self.jobs as usize)
            .set("shards", self.shards as usize)
            .set("input_bytes", self.input_bytes as usize)
            .set("output_bytes", self.output_bytes as usize)
            .set("codec_seconds", self.codec_seconds)
            .set("compressibility", self.compressibility())
            .set("throughput_mbps", self.throughput_mbps())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_are_zero() {
        let m = PipelineMetrics::default();
        assert_eq!(m.compressibility(), 0.0);
        assert_eq!(m.throughput_mbps(), 0.0);
    }

    #[test]
    fn compressibility_math() {
        let m = PipelineMetrics {
            jobs: 1,
            shards: 0,
            input_bytes: 100,
            output_bytes: 85,
            codec_seconds: 0.5,
        };
        assert!((m.compressibility() - 0.15).abs() < 1e-12);
        assert!((m.throughput_mbps() - 100.0 / 0.5 / 1e6).abs() < 1e-12);
    }

    #[test]
    fn json_report_fields() {
        let m = PipelineMetrics {
            jobs: 3,
            shards: 2,
            input_bytes: 1000,
            output_bytes: 900,
            codec_seconds: 1.0,
        };
        let j = m.to_json();
        assert_eq!(j.get("jobs").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
        assert!(j.get("compressibility").unwrap().as_f64().unwrap() > 0.09);
    }
}
