//! Pipeline metrics: compression ratio and throughput accounting for
//! the coordinator (and its JSON report for the CLI).
//!
//! The pipeline's counters themselves live on the coordinator's
//! private [`obs::Registry`](crate::obs::Registry); this struct is the
//! derived, report-facing view ([`Pipeline::metrics`](super::Pipeline)
//! reconstructs it from a registry snapshot).

use crate::util::json::Json;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct PipelineMetrics {
    pub jobs: u64,
    /// Jobs that emitted QLS1 shard bodies (subset of `jobs`).
    pub shards: u64,
    pub input_bytes: u64,
    pub output_bytes: u64,
    /// Total codec wall time across workers (not wall-clock elapsed).
    pub codec_seconds: f64,
}

impl PipelineMetrics {
    /// Fraction of bytes removed (the paper's metric).  `None` when no
    /// input bytes were processed — an empty pipeline has no ratio,
    /// and reporting `0.0` would be indistinguishable from "ran and
    /// compressed nothing away".
    pub fn compressibility(&self) -> Option<f64> {
        if self.input_bytes == 0 {
            return None;
        }
        Some(1.0 - self.output_bytes as f64 / self.input_bytes as f64)
    }

    /// Aggregate codec throughput, MB/s (1e6 bytes).  `None` when no
    /// codec time was recorded (zero denominator).
    pub fn throughput_mbps(&self) -> Option<f64> {
        if self.codec_seconds <= 0.0 {
            return None;
        }
        Some(self.input_bytes as f64 / self.codec_seconds / 1e6)
    }

    pub fn to_json(&self) -> Json {
        let ratio = |v: Option<f64>| match v {
            Some(x) => Json::from(x),
            None => Json::from("n/a"),
        };
        Json::obj()
            .set("jobs", self.jobs as usize)
            .set("shards", self.shards as usize)
            .set("input_bytes", self.input_bytes as usize)
            .set("output_bytes", self.output_bytes as usize)
            .set("codec_seconds", self.codec_seconds)
            .set("compressibility", ratio(self.compressibility()))
            .set("throughput_mbps", ratio(self.throughput_mbps()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_metrics_have_no_ratios() {
        // Regression: both ratios used to silently return 0.0 on a
        // zero denominator, conflating "nothing ran" with "ran and
        // achieved zero".  They are `None` now, rendered "n/a".
        let m = PipelineMetrics::default();
        assert_eq!(m.compressibility(), None);
        assert_eq!(m.throughput_mbps(), None);
        let j = m.to_json();
        assert_eq!(j.get("compressibility").unwrap().as_str(), Some("n/a"));
        assert_eq!(j.get("throughput_mbps").unwrap().as_str(), Some("n/a"));
    }

    #[test]
    fn zero_codec_seconds_only_masks_throughput() {
        let m = PipelineMetrics {
            jobs: 1,
            shards: 0,
            input_bytes: 100,
            output_bytes: 80,
            codec_seconds: 0.0,
        };
        assert!(m.compressibility().is_some());
        assert_eq!(m.throughput_mbps(), None);
    }

    #[test]
    fn compressibility_math() {
        let m = PipelineMetrics {
            jobs: 1,
            shards: 0,
            input_bytes: 100,
            output_bytes: 85,
            codec_seconds: 0.5,
        };
        assert!((m.compressibility().unwrap() - 0.15).abs() < 1e-12);
        assert!(
            (m.throughput_mbps().unwrap() - 100.0 / 0.5 / 1e6).abs() < 1e-12
        );
    }

    #[test]
    fn json_report_fields() {
        let m = PipelineMetrics {
            jobs: 3,
            shards: 2,
            input_bytes: 1000,
            output_bytes: 900,
            codec_seconds: 1.0,
        };
        let j = m.to_json();
        assert_eq!(j.get("jobs").unwrap().as_usize(), Some(3));
        assert_eq!(j.get("shards").unwrap().as_usize(), Some(2));
        assert!(j.get("compressibility").unwrap().as_f64().unwrap() > 0.09);
    }
}
