//! The `qlc serve` client: a blocking QSV1 handshake, then a
//! reactor-driven request/response pump mirroring the server's
//! non-blocking state machine.
//!
//! One [`ServeClient`] speaks one operation (compress or decompress)
//! over one connection; [`ServeClient::request`] streams the chunks
//! of a request up and returns the server's response chunks, recording
//! the whole-request latency into the global
//! `serve_request_latency_ns{backend=...,op=...}` histogram.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use crate::codecs::CodecHandle;
use crate::obs;
use crate::transport::net::serve_wire::{
    self, Handshake, Op, RequestTracker,
};
use crate::transport::net::wire;
use crate::transport::reactor::{self, new_reactor, Interest, Reactor};
use crate::transport::ChunkMsg;

use super::io::{read_some, stream_fd, write_some};

/// Reactor token of the client's single socket.
const TOKEN_SOCK: u64 = 0;

/// Client-side knobs.
#[derive(Clone, Copy, Debug)]
pub struct ClientConfig {
    /// Readiness-wait backend for the response pump.
    pub backend: reactor::Backend,
    /// Hard per-request (and handshake) progress deadline.
    pub timeout: Duration,
    /// Chunk size [`chunks_from_raw`] splits raw payloads at.
    pub chunk: usize,
}

impl Default for ClientConfig {
    fn default() -> ClientConfig {
        ClientConfig {
            backend: reactor::Backend::Auto,
            timeout: Duration::from_secs(30),
            chunk: 64 * 1024,
        }
    }
}

/// One streaming connection to a `qlc serve` server.
pub struct ServeClient {
    stream: TcpStream,
    reactor: Box<dyn Reactor>,
    interest: Interest,
    events: Vec<reactor::Event>,
    op: Op,
    codec_tag: u8,
    next_request: u32,
    resp_tracker: RequestTracker,
    inbuf: Vec<u8>,
    out: Vec<u8>,
    out_pos: usize,
    timeout: Duration,
    latency: obs::Hist,
}

impl ServeClient {
    /// Connect, run the blocking QSV1 handshake (the server's QSA1
    /// ack either opens the stream or carries the rejection reason),
    /// then switch the socket to the non-blocking pump.
    pub fn connect(
        addr: &str,
        handle: &CodecHandle,
        op: Op,
        cfg: &ClientConfig,
    ) -> Result<ServeClient, String> {
        let mut stream = TcpStream::connect(addr)
            .map_err(|e| format!("connect {addr}: {e}"))?;
        let _ = stream.set_nodelay(true);
        stream
            .set_read_timeout(Some(cfg.timeout))
            .map_err(|e| e.to_string())?;

        let hs = Handshake {
            op,
            codec_tag: handle.wire_tag(),
            header: handle.wire_header().to_vec(),
        };
        let mut buf = Vec::new();
        serve_wire::encode_handshake(&hs, &mut buf)?;
        stream
            .write_all(&buf)
            .map_err(|e| format!("handshake send: {e}"))?;

        // Blocking ack read; anything after the ack (there should be
        // nothing, but the protocol does not forbid it) is preserved
        // for the pump.
        let mut inbuf = Vec::new();
        let ack = loop {
            if let Some((ack, used)) = serve_wire::decode_ack(&inbuf)? {
                inbuf.drain(..used);
                break ack;
            }
            let mut chunk = [0u8; 1024];
            let n = stream.read(&mut chunk).map_err(|e| {
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut
                {
                    format!("handshake: no ack within {:?}", cfg.timeout)
                } else {
                    format!("handshake read: {e}")
                }
            })?;
            if n == 0 {
                return Err(
                    "handshake: server closed the connection".to_string()
                );
            }
            inbuf.extend_from_slice(&chunk[..n]);
        };
        if !ack.ok {
            return Err(format!("server rejected handshake: {}", ack.msg));
        }

        stream.set_read_timeout(None).map_err(|e| e.to_string())?;
        stream.set_nonblocking(true).map_err(|e| e.to_string())?;
        let mut reactor = new_reactor(cfg.backend)?;
        reactor.register(
            stream_fd(&stream),
            TOKEN_SOCK,
            Interest::READABLE,
        )?;
        let latency = obs::global().hist(&obs::label(
            "serve_request_latency_ns",
            &[("backend", reactor.name()), ("op", op.name())],
        ));
        Ok(ServeClient {
            stream,
            reactor,
            interest: Interest::READABLE,
            events: Vec::new(),
            op,
            codec_tag: handle.wire_tag(),
            next_request: 0,
            resp_tracker: RequestTracker::new(handle.wire_tag()),
            inbuf,
            out: Vec::new(),
            out_pos: 0,
            timeout: cfg.timeout,
            latency,
        })
    }

    /// Which operation this connection's handshake opened.
    pub fn op(&self) -> Op {
        self.op
    }

    /// Which reactor backend the response pump resolved to.
    pub fn backend_name(&self) -> &'static str {
        self.reactor.name()
    }

    /// Stream one request's chunks up and collect the server's
    /// response chunks.  `chunks` must be pre-stamped: `seq == index`,
    /// `last` exactly on the final chunk.
    pub fn request(
        &mut self,
        chunks: &[ChunkMsg],
    ) -> Result<Vec<ChunkMsg>, String> {
        if chunks.is_empty() {
            return Err("request needs at least one chunk".to_string());
        }
        for (i, c) in chunks.iter().enumerate() {
            if c.seq as usize != i {
                return Err(format!(
                    "chunk {i} stamped seq {}, want {i}",
                    c.seq
                ));
            }
            if c.last != (i + 1 == chunks.len()) {
                return Err(format!("chunk {i} has a misplaced last flag"));
            }
        }
        let hop = self.next_request;
        self.next_request = self
            .next_request
            .checked_add(1)
            .ok_or("request ordinal overflow")?;
        for c in chunks {
            wire::encode_frame(hop, self.codec_tag, c, &mut self.out)?;
        }

        let _span = obs::span("serve.request")
            .arg("op", self.op.name())
            .arg("request", hop)
            .arg("chunks", chunks.len());
        let sw = obs::Stopwatch::start();
        let deadline = Instant::now() + self.timeout;
        let mut responses: Vec<ChunkMsg> = Vec::new();
        'pump: loop {
            let mut progressed = write_some(
                &mut self.stream,
                &mut self.out,
                &mut self.out_pos,
            )? > 0;
            let (read, eof) = read_some(&mut self.stream, &mut self.inbuf)?;
            progressed |= read > 0;

            let mut pos = 0usize;
            while pos < self.inbuf.len() {
                match wire::decode_frame(&self.inbuf[pos..])? {
                    Some((frame, used)) => {
                        pos += used;
                        if frame.hop != hop {
                            self.inbuf.drain(..pos);
                            return Err(format!(
                                "response for request {} while waiting on \
                                 {hop}",
                                frame.hop
                            ));
                        }
                        let done = self.resp_tracker.accept(&frame)?;
                        responses.push(frame.msg);
                        if done {
                            self.inbuf.drain(..pos);
                            break 'pump;
                        }
                    }
                    None => break,
                }
            }
            if pos > 0 {
                self.inbuf.drain(..pos);
                progressed = true;
            }

            if eof {
                return Err(format!(
                    "server closed mid-request ({} of {} response chunks)",
                    responses.len(),
                    chunks.len()
                ));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(format!(
                    "request timed out after {:?}",
                    self.timeout
                ));
            }
            if progressed {
                self.reactor.note_progress();
            }
            self.wait_ready(deadline.saturating_duration_since(now))?;
        }
        self.latency.record(sw.elapsed_ns());
        Ok(responses)
    }

    /// Park on the reactor, watching for writable only while output
    /// is actually queued.
    fn wait_ready(&mut self, timeout: Duration) -> Result<(), String> {
        let want = Interest {
            readable: true,
            writable: self.out_pos < self.out.len(),
        };
        if want != self.interest {
            self.reactor.reregister(
                stream_fd(&self.stream),
                TOKEN_SOCK,
                want,
            )?;
            self.interest = want;
        }
        let mut events = std::mem::take(&mut self.events);
        self.reactor.wait(&mut events, timeout.min(self.timeout))?;
        self.events = events;
        Ok(())
    }
}

/// Split a raw buffer into pre-stamped request chunks of at most
/// `chunk_bytes` each.  Empty input becomes a single empty last chunk
/// so zero-length payloads still round-trip.
pub fn chunks_from_raw(data: &[u8], chunk_bytes: usize) -> Vec<ChunkMsg> {
    let chunk_bytes = chunk_bytes.max(1);
    if data.is_empty() {
        return vec![ChunkMsg {
            seq: 0,
            last: true,
            n_symbols: 0,
            payload: Vec::new(),
            scales: Vec::new(),
        }];
    }
    let n_chunks = data.len().div_ceil(chunk_bytes);
    data.chunks(chunk_bytes)
        .enumerate()
        .map(|(i, c)| ChunkMsg {
            seq: i as u32,
            last: i + 1 == n_chunks,
            n_symbols: c.len(),
            payload: c.to_vec(),
            scales: Vec::new(),
        })
        .collect()
}

/// Concatenate response payloads back into one buffer.
pub fn concat_payloads(chunks: &[ChunkMsg]) -> Vec<u8> {
    let total = chunks.iter().map(|c| c.payload.len()).sum();
    let mut out = Vec::with_capacity(total);
    for c in chunks {
        out.extend_from_slice(&c.payload);
    }
    out
}
