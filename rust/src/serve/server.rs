//! The event-driven `qlc serve` server: one thread, one [`Reactor`],
//! many concurrent client connections.
//!
//! Every connection is a non-blocking state machine: a partial-frame
//! read buffer, a per-connection output queue flushed as the socket
//! accepts bytes, and — after the QSV1 handshake resolves a codec —
//! one [`EncoderSession`]/[`DecoderSession`] pair reused across every
//! request on the connection (codec tables are built once per
//! connection, never per request).
//!
//! Backpressure is per-connection and bounded: once a connection's
//! queued output crosses [`ServerConfig::out_hiwater`] the server
//! stops reading (and stops decoding) *that* connection — its read
//! interest is dropped so the level-triggered reactor does not spin —
//! until the queue drains.  A slow reader therefore stalls only its
//! own stream; the accept loop and every other connection keep
//! running.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::codecs::{
    Codec, CodecHandle, CodecRegistry, DecoderSession, EncoderSession,
};
use crate::obs;
use crate::transport::net::serve_wire::{self, Ack, Op, RequestTracker};
use crate::transport::net::wire;
use crate::transport::reactor::{self, new_reactor, Interest, Reactor};
use crate::transport::ChunkMsg;

use super::io::{listener_fd, read_some, stream_fd, write_some};

/// Reactor token of the accept socket; connections start at 1.
const TOKEN_LISTENER: u64 = 0;

/// How long one reactor wait may park before the loop re-checks the
/// shutdown flag and exit condition.
const WAIT_TICK: Duration = Duration::from_millis(100);

/// A frame that has not completed within this many buffered bytes can
/// only be one that violates the serve chunk caps — tear the
/// connection down instead of buffering toward the (much larger)
/// link-level frame cap.
const INBUF_CAP: usize = serve_wire::MAX_REQ_PAYLOAD + (64 << 10);

/// `qlc serve` configuration.
#[derive(Clone, Copy, Debug)]
pub struct ServerConfig {
    /// Readiness-wait backend for the event loop.
    pub backend: reactor::Backend,
    /// Stop (gracefully: drain live connections, accept no new ones)
    /// after completing this many requests; `0` = run until the
    /// shutdown handle fires.
    pub max_requests: u64,
    /// Accept cap: further connections are closed immediately.
    pub max_conns: usize,
    /// Backpressure high-water mark on one connection's output queue,
    /// in bytes.  Reading (and codec work) for the connection pauses
    /// above it and resumes once the queue drains below it.
    pub out_hiwater: usize,
}

impl Default for ServerConfig {
    fn default() -> ServerConfig {
        ServerConfig {
            backend: reactor::Backend::Auto,
            max_requests: 0,
            max_conns: 256,
            out_hiwater: 4 << 20,
        }
    }
}

/// What a finished [`Server::run`] did.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSummary {
    /// Requests completed (a multi-chunk request counts once).
    pub requests: u64,
    /// Connections accepted over the server's lifetime.
    pub conns: u64,
}

/// Per-connection codec state: the handle owns the codec, the
/// sessions borrow it for the connection's whole lifetime so chunk
/// N+1 of request K+1 reuses the tables (and the session accounting)
/// built for request 0.
struct ConnSessions {
    /// Declared before `handle` so they drop first — both sessions
    /// borrow the codec that `handle` owns.
    enc: EncoderSession<'static>,
    dec: DecoderSession<'static>,
    tracker: RequestTracker,
    op: Op,
    handle: CodecHandle,
}

impl ConnSessions {
    fn new(op: Op, handle: CodecHandle) -> ConnSessions {
        // SAFETY: `handle.codec()` borrows the codec through the
        // `Box<dyn Codec>` inside `handle`; that heap allocation is
        // stable when `handle` moves and lives until `handle` drops.
        // The sessions sit before `handle` in this struct, so they
        // drop first and the 'static-extended borrow never outlives
        // the allocation; the handle is never mutated while they live.
        let codec: &'static dyn Codec =
            unsafe { &*(handle.codec() as *const dyn Codec) };
        ConnSessions {
            enc: EncoderSession::new(codec),
            dec: DecoderSession::new(codec),
            tracker: RequestTracker::new(handle.wire_tag()),
            op,
            handle,
        }
    }

    fn codec_name(&self) -> &str {
        self.handle.name()
    }
}

/// One client connection's non-blocking state machine.
struct Conn {
    stream: TcpStream,
    fd: reactor::RawFd,
    token: u64,
    /// Bytes read but not yet framed.
    inbuf: Vec<u8>,
    /// Outbound bytes the socket has not accepted yet
    /// (`out[out_pos..]`).
    out: Vec<u8>,
    out_pos: usize,
    /// What the reactor currently watches this connection for.
    interest: Interest,
    /// Peer finished sending (EOF on the read side).
    rx_eof: bool,
    /// Tear down once the queued output drains (handshake reject).
    close_after_flush: bool,
    /// `None` until the handshake resolves a codec.
    sessions: Option<ConnSessions>,
    /// Started at the first chunk of the in-flight request.
    req_start: Option<obs::Stopwatch>,
}

impl Conn {
    fn pending_out(&self) -> usize {
        self.out.len() - self.out_pos
    }

    /// Nothing left to do for this peer: it stopped sending (or was
    /// rejected) and every queued response byte has been flushed.
    fn finished(&self) -> bool {
        self.pending_out() == 0 && (self.rx_eof || self.close_after_flush)
    }
}

/// Global-registry counters/histograms for the serve loop.
struct ServeStats {
    conns: obs::Counter,
    conns_over_cap: obs::Counter,
    requests: obs::Counter,
    rejects: obs::Counter,
    conn_errors: obs::Counter,
    bytes_in: obs::Counter,
    bytes_out: obs::Counter,
    backpressure: obs::Counter,
    req_ns_compress: obs::Hist,
    req_ns_decompress: obs::Hist,
}

impl ServeStats {
    fn new() -> ServeStats {
        let reg = obs::global();
        ServeStats {
            conns: reg.counter("serve_conns_total"),
            conns_over_cap: reg.counter("serve_conns_over_cap_total"),
            requests: reg.counter("serve_requests_total"),
            rejects: reg.counter("serve_handshake_rejects_total"),
            conn_errors: reg.counter("serve_conn_errors_total"),
            bytes_in: reg.counter("serve_bytes_in_total"),
            bytes_out: reg.counter("serve_bytes_out_total"),
            backpressure: reg.counter("serve_backpressure_stalls_total"),
            req_ns_compress: reg.hist(&obs::label(
                "serve_request_ns",
                &[("op", "compress")],
            )),
            req_ns_decompress: reg.hist(&obs::label(
                "serve_request_ns",
                &[("op", "decompress")],
            )),
        }
    }
}

/// The streaming compression server.  See the module docs.
pub struct Server {
    listener: TcpListener,
    local_addr: SocketAddr,
    reactor: Box<dyn Reactor>,
    cfg: ServerConfig,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    served: u64,
    accepted: u64,
    stop: Arc<AtomicBool>,
    /// Scratch event buffer reused across waits.
    events: Vec<reactor::Event>,
    stats: ServeStats,
}

impl Server {
    /// Bind the accept socket and set up the event loop.  `addr` may
    /// use port 0 to let the OS pick ([`Server::local_addr`] reports
    /// the real one).
    pub fn bind(addr: &str, cfg: ServerConfig) -> Result<Server, String> {
        let listener =
            TcpListener::bind(addr).map_err(|e| format!("bind {addr}: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("nonblocking listener: {e}"))?;
        let local_addr =
            listener.local_addr().map_err(|e| e.to_string())?;
        let mut reactor = new_reactor(cfg.backend)?;
        reactor.register(
            listener_fd(&listener),
            TOKEN_LISTENER,
            Interest::READABLE,
        )?;
        Ok(Server {
            listener,
            local_addr,
            reactor,
            cfg,
            conns: HashMap::new(),
            next_token: TOKEN_LISTENER + 1,
            served: 0,
            accepted: 0,
            stop: Arc::new(AtomicBool::new(false)),
            events: Vec::new(),
            stats: ServeStats::new(),
        })
    }

    /// The bound address (real port even when bound to port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Which reactor backend the event loop resolved to.
    pub fn backend_name(&self) -> &'static str {
        self.reactor.name()
    }

    /// A flag that makes [`Server::run`] return within one wait tick.
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    /// Has the request target been reached (never true for the
    /// run-forever configuration)?
    fn target_reached(&self) -> bool {
        self.cfg.max_requests > 0 && self.served >= self.cfg.max_requests
    }

    /// Run the event loop until the shutdown handle fires or
    /// `max_requests` requests have completed **and** every live
    /// connection has drained (clients still waiting on queued
    /// responses get them before the loop exits).
    pub fn run(&mut self) -> Result<ServeSummary, String> {
        loop {
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
            if self.target_reached() && self.conns.is_empty() {
                break;
            }
            let mut events = std::mem::take(&mut self.events);
            self.reactor.wait(&mut events, WAIT_TICK)?;
            let mut progressed = false;
            for ev in &events {
                if ev.token == TOKEN_LISTENER {
                    progressed |= self.accept_ready()?;
                } else {
                    progressed |= self.pump_conn(ev.token);
                }
            }
            self.events = events;
            if progressed {
                self.reactor.note_progress();
            }
        }
        Ok(ServeSummary { requests: self.served, conns: self.accepted })
    }

    /// Drain the accept queue.  Connections over the cap (or arriving
    /// after the request target was reached) are closed immediately.
    fn accept_ready(&mut self) -> Result<bool, String> {
        let mut progressed = false;
        loop {
            let stream = match self.listener.accept() {
                Ok((stream, _peer)) => stream,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {
                    continue
                }
                Err(e) => return Err(format!("accept: {e}")),
            };
            progressed = true;
            if self.conns.len() >= self.cfg.max_conns || self.target_reached()
            {
                self.stats.conns_over_cap.inc();
                drop(stream);
                continue;
            }
            if stream.set_nonblocking(true).is_err() {
                continue;
            }
            let _ = stream.set_nodelay(true);
            let fd = stream_fd(&stream);
            let token = self.next_token;
            self.next_token += 1;
            if self.reactor.register(fd, token, Interest::READABLE).is_err() {
                continue;
            }
            self.accepted += 1;
            self.stats.conns.inc();
            self.conns.insert(
                token,
                Conn {
                    stream,
                    fd,
                    token,
                    inbuf: Vec::new(),
                    out: Vec::new(),
                    out_pos: 0,
                    interest: Interest::READABLE,
                    rx_eof: false,
                    close_after_flush: false,
                    sessions: None,
                    req_start: None,
                },
            );
        }
        Ok(progressed)
    }

    /// Drive one connection as far as it will go.  Per-connection
    /// failures (I/O errors, protocol violations, codec errors) tear
    /// that connection down; they never abort the server.
    fn pump_conn(&mut self, token: u64) -> bool {
        let Some(mut conn) = self.conns.remove(&token) else {
            return false;
        };
        match self.drive(&mut conn) {
            Ok(progressed) => {
                if conn.finished() {
                    self.close_conn(conn);
                } else if self.update_interest(&mut conn).is_err() {
                    self.stats.conn_errors.inc();
                    self.close_conn(conn);
                } else {
                    self.conns.insert(token, conn);
                }
                progressed
            }
            Err(_) => {
                self.stats.conn_errors.inc();
                self.close_conn(conn);
                true
            }
        }
    }

    /// Flush, fill and parse until nothing moves.
    fn drive(&mut self, conn: &mut Conn) -> Result<bool, String> {
        let mut progressed = false;
        loop {
            let mut round = self.try_flush(conn)?;
            round |= self.try_fill(conn)?;
            round |= self.process(conn)?;
            if !round {
                break;
            }
            progressed = true;
        }
        Ok(progressed)
    }

    /// Write queued output until the socket pushes back.
    fn try_flush(&mut self, conn: &mut Conn) -> Result<bool, String> {
        let wrote = write_some(
            &mut conn.stream,
            &mut conn.out,
            &mut conn.out_pos,
        )?;
        if wrote > 0 {
            self.stats.bytes_out.add(wrote as u64);
        }
        Ok(wrote > 0)
    }

    /// Read inbound bytes unless the peer is done or the connection
    /// is backpressured.
    fn try_fill(&mut self, conn: &mut Conn) -> Result<bool, String> {
        if conn.rx_eof
            || conn.close_after_flush
            || conn.pending_out() >= self.cfg.out_hiwater
            || conn.inbuf.len() >= INBUF_CAP
        {
            return Ok(false);
        }
        let (read, eof) = read_some(&mut conn.stream, &mut conn.inbuf)?;
        if eof {
            conn.rx_eof = true;
        }
        if read > 0 {
            self.stats.bytes_in.add(read as u64);
        }
        Ok(read > 0 || eof)
    }

    /// Parse and answer everything complete in the read buffer.
    fn process(&mut self, conn: &mut Conn) -> Result<bool, String> {
        let mut pos = 0usize;
        loop {
            if conn.close_after_flush {
                break;
            }
            // Backpressure: stop producing output once the queue is
            // over the high-water mark; the unread frames keep until
            // the flush side drains it.
            if conn.pending_out() >= self.cfg.out_hiwater {
                self.stats.backpressure.inc();
                break;
            }
            if pos >= conn.inbuf.len() {
                break;
            }
            if conn.sessions.is_none() {
                match serve_wire::decode_handshake(&conn.inbuf[pos..]) {
                    Ok(Some((hs, used))) => {
                        pos += used;
                        self.open_session(conn, hs);
                    }
                    Ok(None) => break,
                    Err(e) => {
                        // Malformed handshake: answer with the reason,
                        // then close once the ack flushes.
                        self.stats.rejects.inc();
                        serve_wire::encode_ack(&Ack::err(e), &mut conn.out);
                        conn.close_after_flush = true;
                        break;
                    }
                }
            } else {
                match wire::decode_frame(&conn.inbuf[pos..]) {
                    Ok(Some((frame, used))) => {
                        pos += used;
                        self.handle_frame(conn, frame)?;
                    }
                    Ok(None) => {
                        if conn.inbuf.len() - pos > INBUF_CAP {
                            return Err(
                                "request frame exceeds the serve buffer cap"
                                    .to_string(),
                            );
                        }
                        break;
                    }
                    Err(e) => return Err(format!("request framing: {e}")),
                }
            }
        }
        if pos > 0 {
            conn.inbuf.drain(..pos);
            return Ok(true);
        }
        Ok(false)
    }

    /// Resolve the handshake's codec identity and, on success, build
    /// the connection's long-lived session pair.
    fn open_session(&mut self, conn: &mut Conn, hs: serve_wire::Handshake) {
        match CodecRegistry::global().resolve_wire(hs.codec_tag, &hs.header) {
            Ok(handle) => {
                serve_wire::encode_ack(&Ack::ok(), &mut conn.out);
                conn.sessions = Some(ConnSessions::new(hs.op, handle));
            }
            Err(e) => {
                self.stats.rejects.inc();
                serve_wire::encode_ack(
                    &Ack::err(format!("codec rejected: {e}")),
                    &mut conn.out,
                );
                conn.close_after_flush = true;
            }
        }
    }

    /// One validated request chunk in, one response chunk queued out.
    fn handle_frame(
        &mut self,
        conn: &mut Conn,
        frame: wire::WireFrame,
    ) -> Result<(), String> {
        let Some(sessions) = conn.sessions.as_mut() else {
            return Err("frame before handshake".to_string());
        };
        if sessions.tracker.expected_seq() == 0 {
            conn.req_start = Some(obs::Stopwatch::start());
        }
        let completes = sessions.tracker.accept(&frame)?;
        let _span = obs::span("serve.chunk")
            .arg("op", sessions.op.name())
            .arg("codec", sessions.codec_name())
            .arg("request", frame.hop);
        let (payload, n_symbols) = match sessions.op {
            Op::Compress => {
                // A compress-stream chunk is raw bytes: one symbol per
                // payload byte, by construction.
                if frame.msg.n_symbols != frame.msg.payload.len() {
                    return Err(format!(
                        "compress chunk declares {} symbols for {} raw \
                         bytes",
                        frame.msg.n_symbols,
                        frame.msg.payload.len()
                    ));
                }
                let n = frame.msg.payload.len();
                (sessions.enc.encode_chunk_to_vec(&frame.msg.payload), n)
            }
            Op::Decompress => {
                let n = frame.msg.n_symbols;
                // The tracker already rejects oversized chunks;
                // re-check at the allocation so the bound is local.
                if n > serve_wire::MAX_CHUNK_SYMBOLS {
                    return Err(format!(
                        "decompress chunk declares {n} symbols (cap {})",
                        serve_wire::MAX_CHUNK_SYMBOLS
                    ));
                }
                let mut out = vec![0u8; n];
                sessions
                    .dec
                    .decode_chunk(&frame.msg.payload, &mut out)
                    .map_err(|e| format!("chunk decode: {e}"))?;
                (out, n)
            }
        };
        let resp = ChunkMsg {
            seq: frame.msg.seq,
            last: frame.msg.last,
            n_symbols,
            payload,
            // Block scales ride along unchanged in both directions.
            scales: frame.msg.scales,
        };
        wire::encode_frame(frame.hop, frame.codec_tag, &resp, &mut conn.out)?;
        if completes {
            self.served += 1;
            self.stats.requests.inc();
            let ns = conn
                .req_start
                .take()
                .map(|sw| sw.elapsed_ns())
                .unwrap_or(0);
            match sessions.op {
                Op::Compress => self.stats.req_ns_compress.record(ns),
                Op::Decompress => self.stats.req_ns_decompress.record(ns),
            }
        }
        Ok(())
    }

    /// Keep the reactor's view of this connection in sync: readable
    /// only while we are willing to read (not EOF, not backpressured),
    /// writable only while output is queued.
    fn update_interest(&mut self, conn: &mut Conn) -> Result<(), String> {
        let want = Interest {
            readable: !conn.rx_eof
                && !conn.close_after_flush
                && conn.pending_out() < self.cfg.out_hiwater
                && conn.inbuf.len() < INBUF_CAP,
            writable: conn.pending_out() > 0,
        };
        if want != conn.interest {
            self.reactor.reregister(conn.fd, conn.token, want)?;
            conn.interest = want;
        }
        Ok(())
    }

    fn close_conn(&mut self, conn: Conn) {
        let _ = self.reactor.deregister(conn.fd);
        // `conn.stream` drops (and closes) here.
    }
}
