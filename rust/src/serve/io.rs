//! Shared non-blocking socket plumbing for the serve server and
//! client: raw-fd extraction for reactor registration and the
//! WouldBlock-aware read/write primitives both state machines build
//! on.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

use crate::transport::reactor;

/// How much one `read` call may pull per attempt.
pub(crate) const READ_CHUNK: usize = 64 * 1024;

#[cfg(unix)]
pub(crate) fn stream_fd(s: &TcpStream) -> reactor::RawFd {
    use std::os::fd::AsRawFd;
    s.as_raw_fd()
}

#[cfg(not(unix))]
pub(crate) fn stream_fd(s: &TcpStream) -> reactor::RawFd {
    // No epoll off unix; the fallback reactor only needs a distinct
    // identifier per registration, and the local port number is one.
    s.local_addr().map(|a| a.port() as reactor::RawFd).unwrap_or(0)
}

#[cfg(unix)]
pub(crate) fn listener_fd(l: &TcpListener) -> reactor::RawFd {
    use std::os::fd::AsRawFd;
    l.as_raw_fd()
}

#[cfg(not(unix))]
pub(crate) fn listener_fd(l: &TcpListener) -> reactor::RawFd {
    l.local_addr().map(|a| a.port() as reactor::RawFd).unwrap_or(0)
}

/// Pull whatever the socket has ready into `inbuf`, up to one
/// [`READ_CHUNK`] per inner read.  Returns `(bytes_read, saw_eof)`;
/// WouldBlock simply ends the attempt.
pub(crate) fn read_some(
    stream: &mut TcpStream,
    inbuf: &mut Vec<u8>,
) -> Result<(usize, bool), String> {
    let mut total = 0usize;
    let mut buf = [0u8; READ_CHUNK];
    loop {
        match stream.read(&mut buf) {
            Ok(0) => return Ok((total, true)),
            Ok(n) => {
                inbuf.extend_from_slice(&buf[..n]);
                total += n;
                if n < buf.len() {
                    return Ok((total, false));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                return Ok((total, false));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("socket read: {e}")),
        }
    }
}

/// Push `out[*pos..]` at the socket until it pushes back, compacting
/// the buffer once fully drained.  Returns the byte count accepted.
pub(crate) fn write_some(
    stream: &mut TcpStream,
    out: &mut Vec<u8>,
    pos: &mut usize,
) -> Result<usize, String> {
    let mut total = 0usize;
    while *pos < out.len() {
        match stream.write(&out[*pos..]) {
            Ok(0) => return Err("socket write: wrote 0 bytes".to_string()),
            Ok(n) => {
                *pos += n;
                total += n;
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(format!("socket write: {e}")),
        }
    }
    if *pos >= out.len() {
        out.clear();
        *pos = 0;
    }
    Ok(total)
}
