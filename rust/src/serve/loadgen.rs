//! Concurrent load generator for `qlc serve`: M independent client
//! streams, each running compress→decompress round trips against the
//! server and verifying the round trip bit-exactly, with aggregate
//! throughput and per-op latency quantiles pulled from the global
//! [`obs`] registry.

use std::time::{Duration, Instant};

use crate::codecs::CodecRegistry;
use crate::collective::dist::fnv1a64;
use crate::data::{TensorGen, TensorKind};
use crate::formats::Variant;
use crate::obs;
use crate::stats::Histogram;
use crate::transport::net::serve_wire::Op;
use crate::transport::reactor::{self, new_reactor};
use crate::util::rng::Rng;

use super::client::{
    chunks_from_raw, concat_payloads, ClientConfig, ServeClient,
};

/// Load-generator knobs (the `qlc loadgen` flags, structured).
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address to connect to.
    pub addr: String,
    /// Concurrent client streams; each opens one compress and one
    /// decompress connection.
    pub streams: usize,
    /// Round trips per stream.
    pub requests: usize,
    /// Raw payload bytes per request (rounded down to a multiple of
    /// 32 symbols, minimum 32).
    pub size: usize,
    /// Request chunk size in bytes.
    pub chunk: usize,
    /// Codec name resolved against each stream's own calibration
    /// histogram.
    pub codec: String,
    /// Reactor backend for the client pumps.
    pub backend: reactor::Backend,
    /// Check every round trip against an FNV-1a checksum of the
    /// original payload.
    pub verify: bool,
    /// Base RNG seed; stream `i` forks stream `i + 1` off it.
    pub seed: u64,
    /// Per-request progress deadline.
    pub timeout: Duration,
}

impl Default for LoadgenConfig {
    fn default() -> LoadgenConfig {
        LoadgenConfig {
            addr: String::new(),
            streams: 4,
            requests: 8,
            size: 1 << 20,
            chunk: 64 * 1024,
            codec: "qlc".to_string(),
            backend: reactor::Backend::Auto,
            verify: true,
            seed: 0x10ad,
            timeout: Duration::from_secs(30),
        }
    }
}

/// What a load-generator run measured.
#[derive(Clone, Debug)]
pub struct LoadgenReport {
    pub streams: usize,
    /// Total compress→decompress round trips completed.
    pub requests: u64,
    /// Raw bytes pushed through compression (one direction).
    pub raw_bytes: u64,
    /// Compressed bytes that came back from the compress streams.
    pub wire_bytes: u64,
    pub wall_s: f64,
    /// Raw MB/s through the server counting both directions (each
    /// round trip compresses and then decompresses the payload).
    pub aggregate_mbps: f64,
    /// Round trips that passed the checksum (0 when `verify` is off).
    pub verified: u64,
    pub p50_compress_ns: u64,
    pub p99_compress_ns: u64,
    pub p50_decompress_ns: u64,
    pub p99_decompress_ns: u64,
    /// Reactor backend the clients resolved to.
    pub backend: String,
}

struct StreamTotals {
    raw_bytes: u64,
    wire_bytes: u64,
    requests: u64,
    verified: u64,
}

/// Run the load: M scoped worker threads, each with its own data,
/// calibration, codec handle and connection pair.
pub fn run_loadgen(cfg: &LoadgenConfig) -> Result<LoadgenReport, String> {
    if cfg.streams == 0 || cfg.requests == 0 {
        return Err("loadgen needs at least one stream and one request"
            .to_string());
    }
    // Resolve the backend label once so the quantile lookup below
    // reads the same histogram the clients record into.
    let backend_label = new_reactor(cfg.backend)?.name();

    let start = Instant::now();
    let totals: Vec<Result<StreamTotals, String>> =
        std::thread::scope(|scope| {
            let handles: Vec<_> = (0..cfg.streams)
                .map(|idx| scope.spawn(move || run_stream(cfg, idx)))
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join().unwrap_or_else(|_| {
                        Err("stream worker panicked".to_string())
                    })
                })
                .collect()
        });
    let wall_s = start.elapsed().as_secs_f64().max(1e-9);

    let mut raw_bytes = 0u64;
    let mut wire_bytes = 0u64;
    let mut requests = 0u64;
    let mut verified = 0u64;
    for t in totals {
        let t = t?;
        raw_bytes += t.raw_bytes;
        wire_bytes += t.wire_bytes;
        requests += t.requests;
        verified += t.verified;
    }

    let quant = |op: &str, q: f64| -> u64 {
        obs::global()
            .hist(&obs::label(
                "serve_request_latency_ns",
                &[("backend", backend_label), ("op", op)],
            ))
            .quantile(q)
            .unwrap_or(0)
    };
    Ok(LoadgenReport {
        streams: cfg.streams,
        requests,
        raw_bytes,
        wire_bytes,
        wall_s,
        // Each round trip moves the raw payload through the codec
        // twice (compress up, decompress back).
        aggregate_mbps: 2.0 * raw_bytes as f64 / wall_s / 1e6,
        verified,
        p50_compress_ns: quant("compress", 0.50),
        p99_compress_ns: quant("compress", 0.99),
        p50_decompress_ns: quant("decompress", 0.50),
        p99_decompress_ns: quant("decompress", 0.99),
        backend: backend_label.to_string(),
    })
}

/// One stream: deterministic e4m3 symbol payload, per-stream codec
/// calibration, a connection pair, `requests` round trips.
fn run_stream(cfg: &LoadgenConfig, idx: usize) -> Result<StreamTotals, String> {
    let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
    let mut base = Rng::new(cfg.seed);
    let mut rng = base.fork(idx as u64 + 1);
    let n = (cfg.size - cfg.size % 32).max(32);
    let data = gen.symbols(&mut rng, n);
    let hist = Histogram::from_symbols(&data);
    let handle = CodecRegistry::global().resolve(&cfg.codec, &hist)?;
    let want_sum = fnv1a64(&data);

    let ccfg = ClientConfig {
        backend: cfg.backend,
        timeout: cfg.timeout,
        chunk: cfg.chunk,
    };
    let mut comp =
        ServeClient::connect(&cfg.addr, &handle, Op::Compress, &ccfg)?;
    let mut deco =
        ServeClient::connect(&cfg.addr, &handle, Op::Decompress, &ccfg)?;

    let mut totals = StreamTotals {
        raw_bytes: 0,
        wire_bytes: 0,
        requests: 0,
        verified: 0,
    };
    let chunks = chunks_from_raw(&data, cfg.chunk);
    for _ in 0..cfg.requests {
        let compressed = comp.request(&chunks)?;
        totals.raw_bytes += data.len() as u64;
        totals.wire_bytes +=
            compressed.iter().map(|c| c.payload.len() as u64).sum::<u64>();
        // The compress responses are already stamped as a valid
        // request stream (same seq/last, n_symbols = raw chunk size),
        // so they feed the decompress connection unchanged.
        let raw_back = deco.request(&compressed)?;
        totals.requests += 1;
        if cfg.verify {
            let got = concat_payloads(&raw_back);
            if got.len() != data.len() || fnv1a64(&got) != want_sum {
                return Err(format!(
                    "stream {idx}: round trip mismatch ({} bytes back, \
                     {} sent)",
                    got.len(),
                    data.len()
                ));
            }
            totals.verified += 1;
        }
    }
    Ok(totals)
}
