//! The `qlc serve` streaming compression service.
//!
//! Three pieces over the [`crate::transport::reactor`] event loop and
//! the [`crate::transport::net::serve_wire`] session protocol:
//!
//! * [`server`] — the single-threaded, readiness-driven [`Server`]:
//!   many concurrent connections, per-connection session reuse,
//!   bounded per-connection output queues (a slow reader stalls only
//!   its own stream);
//! * [`client`] — [`ServeClient`], the matching request/response
//!   pump, plus the [`chunks_from_raw`]/[`concat_payloads`] chunking
//!   helpers;
//! * [`loadgen`] — [`run_loadgen`], M concurrent verified
//!   compress→decompress round-trip streams reporting aggregate MB/s
//!   and per-op p50/p99 latency.
//!
//! Protocol in one paragraph: a client opens a TCP connection, sends
//! one QSV1 handshake naming the operation and the codec identity
//! (wire tag + serialized table header, exactly what
//! [`CodecHandle::wire_header`](crate::codecs::CodecHandle) emits),
//! and receives a QSA1 ack.  From then on the connection is a stream
//! of QWC1 frames: `hop` numbers the request, `seq` the chunk within
//! it, `FLAG_LAST` ends the request, and the server answers every
//! request frame with exactly one response frame under the same
//! `(hop, seq)`.  Compress streams carry raw symbol bytes up and
//! compressed chunks back; decompress streams the reverse.

pub mod client;
mod io;
pub mod loadgen;
pub mod server;

pub use client::{
    chunks_from_raw, concat_payloads, ClientConfig, ServeClient,
};
pub use loadgen::{run_loadgen, LoadgenConfig, LoadgenReport};
pub use server::{ServeSummary, Server, ServerConfig};
