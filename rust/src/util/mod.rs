//! Infrastructure substrates implemented in-crate (the environment is
//! offline, so no `rand`/`serde`/`clap`/`criterion`): deterministic RNG,
//! minimal JSON, a micro-bench harness and a property-test runner.

pub mod bench;
pub mod cli;
pub mod json;
pub mod prop;
pub mod rng;
