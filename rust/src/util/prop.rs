//! Property-test runner (proptest is unavailable offline).
//!
//! Runs a property over many seeded random cases; on failure it retries
//! with progressively smaller size hints (a lightweight stand-in for
//! shrinking) and reports the failing seed so the case is reproducible.

use crate::util::rng::Rng;

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub base_seed: u64,
    /// Maximum "size" hint passed to the generator (e.g. max vec length).
    pub max_size: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 128, base_seed: 0x9D5C_B0DE, max_size: 4096 }
    }
}

/// True when iteration counts should shrink: under Miri, or when the
/// `QLC_MIRI=1` environment variable is set (the CI Miri job sets it
/// so host-compiled helpers agree with the interpreted crate).
pub fn reduced() -> bool {
    cfg!(miri) || std::env::var("QLC_MIRI").map_or(false, |v| v == "1")
}

/// Scale an iteration/case count down for interpreted or
/// explicitly-reduced runs: `reduced` when [`reduced`] holds, `full`
/// otherwise.  Heavy loops in tests and benches route their counts
/// through this so the Miri job finishes in minutes, not days.
pub fn scaled(full: usize, reduced_count: usize) -> usize {
    if reduced() {
        reduced_count.min(full)
    } else {
        full
    }
}

/// Run `prop(rng, size)`; panics with the failing seed on the first
/// counterexample, after trying to re-fail at smaller sizes.  Case
/// counts shrink automatically under Miri / `QLC_MIRI=1` (see
/// [`scaled`]).
pub fn check<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    let cases = scaled(cfg.cases, 4);
    let cfg = Config { cases, ..cfg };
    for case in 0..cfg.cases {
        let seed = cfg.base_seed ^ (case as u64).wrapping_mul(0xA24B_AED4_963E_E407);
        // Ramp sizes: small cases first to catch edge conditions early.
        let size = 1 + (cfg.max_size.saturating_sub(1)) * case / cfg.cases.max(1);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // "Shrink": re-run the same seed at smaller sizes and report
            // the smallest size that still fails.
            let mut smallest = (size, msg.clone());
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(seed);
                match prop(&mut rng, s) {
                    Err(m) => smallest = (s, m),
                    Ok(()) => {}
                }
                if s == 1 {
                    break;
                }
                s /= 2;
            }
            // lint: infallible(property-test harness: panicking with
            // the reproducible failing seed IS this API's contract)
            panic!(
                "property '{name}' failed (seed={seed:#x}, case={case}, \
                 size={}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Convenience: random byte vector of length ≤ size (biased toward a few
/// distinct symbols half the time — compression codecs care about skew).
pub fn arb_bytes(rng: &mut Rng, size: usize) -> Vec<u8> {
    let len = rng.below(size as u64 + 1) as usize;
    let skewed = rng.uniform() < 0.5;
    let alphabet = if skewed { 1 + rng.below(8) as usize } else { 256 };
    (0..len).map(|_| rng.below(alphabet as u64) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("tautology", Config { cases: 16, ..Config::default() },
              |_, _| Ok(()));
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_seed() {
        check("fails", Config { cases: 4, ..Config::default() }, |_, _| {
            Err("nope".into())
        });
    }

    #[test]
    fn scaled_picks_a_consistent_count() {
        let n = scaled(1000, 8);
        assert_eq!(n, if reduced() { 8 } else { 1000 });
        // The reduced count never exceeds the full count.
        assert_eq!(scaled(5, 8), 5);
    }

    #[test]
    fn arb_bytes_respects_size() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            assert!(arb_bytes(&mut rng, 10).len() <= 10);
        }
    }

    #[test]
    fn arb_bytes_sometimes_skewed() {
        let mut rng = Rng::new(2);
        let mut saw_skew = false;
        for _ in 0..50 {
            let v = arb_bytes(&mut rng, 512);
            if v.len() > 100 {
                let distinct = v.iter().collect::<std::collections::HashSet<_>>();
                if distinct.len() <= 8 {
                    saw_skew = true;
                }
            }
        }
        assert!(saw_skew);
    }
}
