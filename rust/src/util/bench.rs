//! Micro-benchmark harness (criterion is unavailable offline).
//!
//! Provides warmup, multi-sample timing, mean/stddev/median, and
//! throughput reporting.  The `rust/benches/*.rs` binaries (declared
//! `harness = false`) use this to print criterion-style lines; output is
//! parsed by nothing — it is for EXPERIMENTS.md and humans.

use std::time::{Duration, Instant};

#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub min_samples: usize,
    pub max_samples: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(1),
            min_samples: 10,
            max_samples: 200,
        }
    }
}

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<Duration>,
    /// Bytes processed per iteration (0 = don't report throughput).
    pub bytes_per_iter: u64,
}

impl BenchResult {
    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len().max(1) as u32
    }

    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    pub fn stddev(&self) -> Duration {
        let mean = self.mean().as_secs_f64();
        let var = self
            .samples
            .iter()
            .map(|d| (d.as_secs_f64() - mean).powi(2))
            .sum::<f64>()
            / self.samples.len().max(1) as f64;
        Duration::from_secs_f64(var.sqrt())
    }

    /// MB/s (1e6 bytes) at the median sample.
    pub fn throughput_mbps(&self) -> f64 {
        if self.bytes_per_iter == 0 {
            return 0.0;
        }
        self.bytes_per_iter as f64 / self.median().as_secs_f64() / 1e6
    }

    pub fn report(&self) -> String {
        let tp = if self.bytes_per_iter > 0 {
            format!("  {:>9.1} MB/s", self.throughput_mbps())
        } else {
            String::new()
        };
        format!(
            "{:<44} median {:>11.3?}  mean {:>11.3?} ± {:>9.3?}  (n={}){}",
            self.name,
            self.median(),
            self.mean(),
            self.stddev(),
            self.samples.len(),
            tp
        )
    }
}

pub struct Bencher {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Bencher {
    pub fn new() -> Self {
        Bencher::with_config(BenchConfig::default())
    }

    pub fn with_config(cfg: BenchConfig) -> Self {
        Bencher { cfg, results: Vec::new() }
    }

    /// Time `f` (which must consume its own inputs internally).
    pub fn bench<F: FnMut()>(&mut self, name: &str, f: F) -> &BenchResult {
        self.bench_bytes(name, 0, f)
    }

    /// Time `f`, reporting throughput over `bytes` per iteration.
    pub fn bench_bytes<F: FnMut()>(
        &mut self,
        name: &str,
        bytes: u64,
        mut f: F,
    ) -> &BenchResult {
        // Warmup.
        let start = Instant::now();
        while start.elapsed() < self.cfg.warmup {
            std::hint::black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let start = Instant::now();
        while (start.elapsed() < self.cfg.measure
            || samples.len() < self.cfg.min_samples)
            && samples.len() < self.cfg.max_samples
        {
            let t = Instant::now();
            std::hint::black_box(f());
            samples.push(t.elapsed());
        }
        let result = BenchResult {
            name: name.to_string(),
            samples,
            bytes_per_iter: bytes,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher::new()
    }
}

/// Quick-and-dirty config for use inside `cargo test` (milliseconds).
pub fn fast_config() -> BenchConfig {
    BenchConfig {
        warmup: Duration::from_millis(5),
        measure: Duration::from_millis(20),
        min_samples: 3,
        max_samples: 20,
    }
}

/// True when `QLC_BENCH_SMOKE` is set in the environment: benches
/// shrink their inputs and measurement windows so CI can execute every
/// bench binary once, cheaply, and bench code cannot rot.
pub fn smoke() -> bool {
    std::env::var_os("QLC_BENCH_SMOKE").is_some()
}

/// `full` normally, `reduced` under [`smoke`].
pub fn smoke_scaled(full: usize, reduced: usize) -> usize {
    if smoke() {
        reduced
    } else {
        full
    }
}

/// [`fast_config`] under [`smoke`], the default config otherwise.
pub fn smoke_config() -> BenchConfig {
    if smoke() {
        fast_config()
    } else {
        BenchConfig::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn collects_samples() {
        let mut b = Bencher::with_config(fast_config());
        let r = b.bench("noop", || {});
        assert!(r.samples.len() >= 3);
    }

    #[test]
    fn throughput_positive() {
        let mut b = Bencher::with_config(fast_config());
        let data = vec![1u8; 64 * 1024];
        let r = b.bench_bytes("sum", data.len() as u64, || {
            std::hint::black_box(data.iter().map(|&x| x as u64).sum::<u64>());
        });
        assert!(r.throughput_mbps() > 1.0);
    }

    #[test]
    fn stats_sane() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![
                Duration::from_micros(10),
                Duration::from_micros(20),
                Duration::from_micros(30),
            ],
            bytes_per_iter: 0,
        };
        assert_eq!(r.mean(), Duration::from_micros(20));
        assert_eq!(r.median(), Duration::from_micros(20));
        assert!(r.stddev() > Duration::ZERO);
    }
}
