//! Deterministic pseudo-random number generation.
//!
//! xoshiro256++ seeded via SplitMix64 — fast, well-distributed, and
//! reproducible across runs/platforms (all experiment seeds in
//! EXPERIMENTS.md assume this generator).  Includes the distribution
//! samplers the data generators need (normal, lognormal, laplace,
//! student-t-ish heavy tails).

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the Box-Muller transform.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-shard / per-worker RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // Avoid u == 0 (log of zero).
        let u = loop {
            let u = self.uniform();
            if u > 0.0 {
                break u;
            }
        };
        let v = self.uniform();
        let r = (-2.0 * u.ln()).sqrt();
        let (sin, cos) = (2.0 * std::f64::consts::PI * v).sin_cos();
        self.spare_normal = Some(r * sin);
        r * cos
    }

    pub fn normal_scaled(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Laplace(0, b): heavier tails than normal.
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.uniform() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Student-t with `nu` degrees of freedom (ratio-of-normals for the
    /// chi-square via sum of squares; fine for nu ≤ ~30).
    pub fn student_t(&mut self, nu: usize) -> f64 {
        let z = self.normal();
        let chi2: f64 = (0..nu).map(|_| self.normal().powi(2)).sum();
        z / (chi2 / nu as f64).sqrt()
    }

    /// Fill a slice with standard normals (f32).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], mean: f32, std: f32) {
        for v in out.iter_mut() {
            *v = self.normal_scaled(mean as f64, std as f64) as f32;
        }
    }

    /// Random bytes.
    pub fn fill_bytes(&mut self, out: &mut [u8]) {
        let mut chunks = out.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Sample an index from a discrete PMF (linear scan; use
    /// [`AliasTable`] for bulk sampling).
    pub fn categorical(&mut self, pmf: &[f64]) -> usize {
        let mut u = self.uniform();
        for (i, &p) in pmf.iter().enumerate() {
            u -= p;
            if u < 0.0 {
                return i;
            }
        }
        pmf.len() - 1
    }
}

/// Walker alias method for O(1) categorical sampling — used by the
/// calibrated PMF generators to synthesize multi-MB symbol streams.
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
}

impl AliasTable {
    pub fn new(pmf: &[f64]) -> Self {
        let n = pmf.len();
        assert!(n > 0);
        let total: f64 = pmf.iter().sum();
        assert!(total > 0.0, "pmf must have positive mass");
        let mut scaled: Vec<f64> =
            pmf.iter().map(|&p| p * n as f64 / total).collect();
        let mut prob = vec![0.0; n];
        let mut alias = vec![0usize; n];
        let mut small: Vec<usize> = Vec::with_capacity(n);
        let mut large: Vec<usize> = Vec::with_capacity(n);
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i);
            } else {
                large.push(i);
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &i in large.iter().chain(small.iter()) {
            prob[i] = 1.0;
        }
        AliasTable { prob, alias }
    }

    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let n = self.prob.len();
        let i = rng.below(n as u64) as usize;
        if rng.uniform() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    pub fn sample_many(&self, rng: &mut Rng, count: usize) -> Vec<u8> {
        assert!(self.prob.len() <= 256);
        (0..count).map(|_| self.sample(rng) as u8).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn laplace_variance() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let b = 1.5;
        let var = (0..n)
            .map(|_| r.laplace(b).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((var - 2.0 * b * b).abs() < 0.2, "var {var}");
    }

    #[test]
    fn lognormal_positive() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.lognormal(0.0, 0.5) > 0.0);
        }
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fill_bytes_lengths() {
        let mut r = Rng::new(19);
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65] {
            let mut buf = vec![0u8; len];
            r.fill_bytes(&mut buf);
            if len >= 8 {
                assert!(buf.iter().any(|&b| b != 0));
            }
        }
    }

    #[test]
    fn alias_table_matches_pmf() {
        let pmf = [0.5, 0.25, 0.125, 0.125];
        let table = AliasTable::new(&pmf);
        let mut r = Rng::new(23);
        let n = 400_000;
        let mut counts = [0usize; 4];
        for _ in 0..n {
            counts[table.sample(&mut r)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let p = c as f64 / n as f64;
            assert!((p - pmf[i]).abs() < 0.01, "sym {i}: {p} vs {}", pmf[i]);
        }
    }

    #[test]
    fn alias_table_degenerate() {
        let pmf = [0.0, 1.0, 0.0];
        let table = AliasTable::new(&pmf);
        let mut r = Rng::new(29);
        for _ in 0..100 {
            assert_eq!(table.sample(&mut r), 1);
        }
    }

    #[test]
    fn categorical_covers_support() {
        let mut r = Rng::new(31);
        let pmf = [0.3, 0.7];
        let mut saw = [false; 2];
        for _ in 0..200 {
            saw[r.categorical(&pmf)] = true;
        }
        assert!(saw[0] && saw[1]);
    }

    #[test]
    fn student_t_heavier_than_normal() {
        let mut r = Rng::new(37);
        let n = 50_000;
        let extreme_t = (0..n).filter(|_| r.student_t(3).abs() > 3.0).count();
        let extreme_n = (0..n).filter(|_| r.normal().abs() > 3.0).count();
        assert!(extreme_t > 2 * extreme_n, "{extreme_t} vs {extreme_n}");
    }
}
