//! Tiny command-line argument parser (clap is unavailable offline).
//!
//! Model: `qlc <subcommand> [--key value]... [--flag]... [positional]...`

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}
impl std::error::Error for CliError {}

/// Option names that take a value; everything else starting with `--`
/// is treated as a boolean flag.
pub fn parse(
    argv: &[String],
    value_opts: &[&str],
) -> Result<Args, CliError> {
    let mut args = Args::default();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            // --key=value form
            if let Some((k, v)) = name.split_once('=') {
                if !value_opts.contains(&k) {
                    return Err(CliError(format!("unknown option --{k}")));
                }
                args.options.insert(k.to_string(), v.to_string());
            } else if value_opts.contains(&name) {
                let v = it.next().ok_or_else(|| {
                    CliError(format!("--{name} requires a value"))
                })?;
                args.options.insert(name.to_string(), v.clone());
            } else {
                args.flags.push(name.to_string());
            }
        } else if args.subcommand.is_none() && args.positional.is_empty() {
            args.subcommand = Some(a.clone());
        } else {
            args.positional.push(a.clone());
        }
    }
    Ok(args)
}

impl Args {
    pub fn opt(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn opt_or(&self, key: &str, default: &str) -> String {
        self.opt(key).unwrap_or(default).to_string()
    }

    /// The option's value, or an error naming it — for options a
    /// subcommand requires even though the parser treats them as
    /// optional.
    pub fn require(&self, key: &str) -> Result<&str, CliError> {
        self.opt(key)
            .ok_or_else(|| CliError(format!("--{key} VALUE is required")))
    }

    pub fn opt_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                CliError(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn opt_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                CliError(format!("--{key} expects a number, got '{v}'"))
            }),
        }
    }

    pub fn opt_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.opt(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                CliError(format!("--{key} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&v(&["tables", "--fig", "1", "--json"]), &["fig"])
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("tables"));
        assert_eq!(a.opt("fig"), Some("1"));
        assert!(a.has_flag("json"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&v(&["x", "--n=32"]), &["n"]).unwrap();
        assert_eq!(a.opt_usize("n", 0).unwrap(), 32);
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&v(&["compress", "in.bin", "out.bin"]), &[]).unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("compress"));
        assert_eq!(a.positional, v(&["in.bin", "out.bin"]));
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&v(&["x", "--n"]), &["n"]).is_err());
    }

    #[test]
    fn unknown_eq_option_errors() {
        assert!(parse(&v(&["x", "--wat=1"]), &["n"]).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&v(&["x"]), &["n"]).unwrap();
        assert_eq!(a.opt_usize("n", 7).unwrap(), 7);
        assert_eq!(a.opt_f64("r", 0.5).unwrap(), 0.5);
        assert_eq!(a.opt_or("mode", "fast"), "fast");
    }

    #[test]
    fn bad_number_errors() {
        let a = parse(&v(&["x", "--n", "abc"]), &["n"]).unwrap();
        assert!(a.opt_usize("n", 0).is_err());
    }

    #[test]
    fn require_names_the_missing_option() {
        let a = parse(&v(&["x", "--n", "3"]), &["n", "world"]).unwrap();
        assert_eq!(a.require("n").unwrap(), "3");
        let err = a.require("world").unwrap_err();
        assert!(err.to_string().contains("--world"), "{err}");
    }
}
