//! Minimal JSON parser/emitter (serde is unavailable offline).
//!
//! Supports the full JSON value model; used for `artifacts/manifest.json`,
//! scheme/LUT serialization (`codecs::qlc::serde`) and experiment reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---- constructors -------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(mut self, key: &str, val: impl Into<Json>) -> Json {
        if let Json::Obj(m) = &mut self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    // ---- accessors -----------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- parse ---------------------------------------------------------
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), pos: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- emit ----------------------------------------------------------
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.emit(&mut s, 0, true);
        s
    }

    fn emit(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => emit_string(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.emit(out, indent + 1, pretty);
                }
                if !v.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    emit_string(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    val.emit(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn emit_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(n: f64) -> Json {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Json {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Json {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Json {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.pos }
    }

    fn ws(&mut self) {
        while self.pos < self.b.len()
            && matches!(self.b[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs unsupported (not needed for
                            // our manifests); map to replacement char.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Advance over one UTF-8 scalar.
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.b[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let back = Json::parse(&v.to_string_pretty()).unwrap();
            assert_eq!(v, back, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().idx(2).unwrap().get("b").unwrap()
                   .as_str(), Some("x"));
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_real_manifest_shape() {
        let text = r#"{
          "ffn_step": {
            "hlo": "ffn_step.hlo.txt",
            "inputs": [{"name": "x", "shape": [256, 256]}],
            "outputs": [{"name": "ffn1_act", "symbols_shape": [4096, 32],
                         "scales_shape": [4096]}]
          }
        }"#;
        let v = Json::parse(text).unwrap();
        let outs = v.get("ffn_step").unwrap().get("outputs").unwrap();
        assert_eq!(
            outs.idx(0).unwrap().get("symbols_shape").unwrap()
                .idx(0).unwrap().as_usize(),
            Some(4096)
        );
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".to_string());
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }

    #[test]
    fn unicode_string() {
        let v = Json::parse("\"héllo → 🌍\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo → 🌍"));
    }

    #[test]
    fn u_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }

    #[test]
    fn errors() {
        for bad in ["{", "[1,", "tru", "\"abc", "{\"a\" 1}", "1 2", ""] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn builder_api() {
        let v = Json::obj()
            .set("name", "qlc")
            .set("n", 3usize)
            .set("ok", true)
            .set("xs", vec![1.0f64, 2.0]);
        let text = v.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("n").unwrap().as_usize(), Some(3));
        assert_eq!(back.get("xs").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string_pretty(), "42");
        assert_eq!(Json::Num(0.5).to_string_pretty(), "0.5");
    }
}
