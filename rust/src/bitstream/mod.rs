//! MSB-first bit-level I/O — the substrate of every codec in this crate.
//!
//! The writer packs bits big-endian-within-byte (the first bit written
//! becomes the MSB of byte 0), matching the paper's code layout where
//! the 3-bit area prefix leads the code.  The reader keeps a 64-bit
//! staging buffer refilled 32 bits at a time so that `read_bits`/`peek`
//! on the decode hot path are branch-light (see EXPERIMENTS.md §Perf).

/// Bit-granular writer over a growable byte buffer.
///
/// Hot path (EXPERIMENTS.md §Perf): a 64-bit accumulator holding up to
/// 7 residual bits between calls; `write_bits` is one shift-or plus a
/// whole-byte drain — no per-bit loop.
#[derive(Clone, Debug, Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Accumulator; the low `nbits` bits are pending output (bits above
    /// `nbits` are stale and ignored).
    acc: u64,
    nbits: u32,
    total_bits: u64,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        BitWriter { buf: Vec::with_capacity(bytes), ..Self::default() }
    }

    /// Write the low `n` bits of `value`, MSB first. `n` ≤ 57 (enough
    /// for any code in this crate; Huffman caps at 48, QLC at 11).
    #[inline]
    pub fn write_bits(&mut self, value: u64, n: u32) {
        debug_assert!(n <= 57);
        debug_assert!(n == 64 || value < (1u64 << n));
        // nbits < 8 between calls, so nbits + n ≤ 64 always holds.
        self.total_bits += n as u64;
        self.acc = (self.acc << n) | value;
        self.nbits += n;
        while self.nbits >= 8 {
            self.nbits -= 8;
            self.buf.push((self.acc >> self.nbits) as u8);
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, bit: bool) {
        self.write_bits(bit as u64, 1);
    }

    /// Write `n` zero bits (unary padding, Elias prefixes).
    #[inline]
    pub fn write_zeros(&mut self, mut n: u32) {
        while n > 32 {
            self.write_bits(0, 32);
            n -= 32;
        }
        if n > 0 {
            self.write_bits(0, n);
        }
    }

    /// Number of bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.total_bits
    }

    /// Flush (zero-pad the final partial byte) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc << (8 - self.nbits)) as u8);
        }
        self.buf
    }

    /// Flush (zero-pad the final partial byte), append the encoded
    /// bytes to `out`, and reset for the next chunk — the writer's
    /// internal allocation is retained, which is what lets an
    /// [`crate::codecs::EncoderSession`] encode an unbounded stream of
    /// chunks with a single scratch buffer.
    pub fn drain_into(&mut self, out: &mut Vec<u8>) {
        if self.nbits > 0 {
            self.buf.push((self.acc << (8 - self.nbits)) as u8);
        }
        out.extend_from_slice(&self.buf);
        self.reset();
    }

    /// Discard all pending output, keeping the allocation.
    pub fn reset(&mut self) {
        self.buf.clear();
        self.acc = 0;
        self.nbits = 0;
        self.total_bits = 0;
    }
}

/// Bit-granular reader with a 64-bit staging buffer.
#[derive(Clone, Debug)]
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Next byte to load into the staging word.
    byte_pos: usize,
    /// Staging word: next bit to deliver is the MSB.
    word: u64,
    /// Valid bits in `word`.
    avail: u32,
    /// Total bits consumed.
    consumed: u64,
}

#[derive(Debug, PartialEq, Eq)]
pub struct BitstreamEof;

impl std::fmt::Display for BitstreamEof {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "bitstream exhausted")
    }
}
impl std::error::Error for BitstreamEof {}

impl<'a> BitReader<'a> {
    pub fn new(data: &'a [u8]) -> Self {
        BitReader { data, byte_pos: 0, word: 0, avail: 0, consumed: 0 }
    }

    /// Refill the staging word to ≥ 57 valid bits (if input remains).
    /// Fast path: one unaligned 8-byte load, masked to the bytes that
    /// fit (EXPERIMENTS.md §Perf — the byte loop was the decode
    /// bottleneck).
    #[inline]
    fn refill(&mut self) {
        if self.avail > 56 {
            return;
        }
        let rem = self.data.len() - self.byte_pos;
        if rem >= 8 {
            let w = u64::from_be_bytes(
                self.data[self.byte_pos..self.byte_pos + 8]
                    .try_into()
                    .unwrap(),
            );
            let take_bytes = ((64 - self.avail) / 8) as usize; // 1..=8
            // Keep only the bytes we account for; the rest reloads next
            // time at the right offset.
            let keep = w & (!0u64).wrapping_shl(64 - take_bytes as u32 * 8);
            self.word |= keep >> self.avail;
            self.byte_pos += take_bytes;
            self.avail += take_bytes as u32 * 8;
        } else {
            while self.avail <= 56 && self.byte_pos < self.data.len() {
                let b = self.data[self.byte_pos] as u64;
                self.byte_pos += 1;
                self.word |= b << (56 - self.avail);
                self.avail += 8;
            }
        }
    }

    /// Peek up to 32 bits without consuming (zero-padded past EOF).
    #[inline]
    pub fn peek(&mut self, n: u32) -> u32 {
        debug_assert!(n <= 32);
        self.refill();
        if n == 0 {
            return 0;
        }
        (self.word >> (64 - n)) as u32
    }

    /// Refill and report how many valid bits are buffered (≤ 64).
    /// Bulk decoders use this to run a checked-once inner loop
    /// (EXPERIMENTS.md §Perf).
    #[inline]
    pub fn buffered_bits(&mut self) -> u32 {
        self.refill();
        self.avail
    }

    /// Peek from the buffer without refilling.  The caller must have
    /// ensured `buffered_bits() ≥ n` on this position.
    #[inline]
    pub fn peek_buffered(&self, n: u32) -> u32 {
        debug_assert!(n <= 32 && (n <= self.avail || n == 0));
        if n == 0 {
            return 0;
        }
        (self.word >> (64 - n)) as u32
    }

    /// The raw staging word (valid in its top `buffered_bits()` bits).
    /// Bulk decoders combine this with precomputed shifts to avoid
    /// re-normalizing per symbol.
    #[inline]
    pub fn word_buffered(&self) -> u64 {
        self.word
    }

    /// Consume `n` bits previously peeked. Safe to over-consume into the
    /// zero padding only if the caller tracks its own end (the framed
    /// codecs all carry an element count).
    #[inline]
    pub fn skip(&mut self, n: u32) {
        debug_assert!(n <= self.avail.max(32));
        self.word <<= n;
        self.avail = self.avail.saturating_sub(n);
        self.consumed += n as u64;
    }

    /// Read `n` ≤ 32 bits MSB-first, checking for EOF.
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> Result<u32, BitstreamEof> {
        if self.remaining_bits() < n as u64 {
            return Err(BitstreamEof);
        }
        let v = self.peek(n);
        self.skip(n);
        Ok(v)
    }

    /// Read a single bit.
    #[inline]
    pub fn read_bit(&mut self) -> Result<bool, BitstreamEof> {
        Ok(self.read_bits(1)? != 0)
    }

    /// Count and consume leading zero bits up to the next 1 bit, then
    /// consume the 1 bit. Returns the zero count (Elias/EG prefixes).
    pub fn read_unary(&mut self) -> Result<u32, BitstreamEof> {
        let mut zeros = 0u32;
        loop {
            self.refill();
            if self.avail == 0 {
                return Err(BitstreamEof);
            }
            let chunk = (self.word >> 32) as u32;
            let lz = chunk.leading_zeros().min(self.avail);
            if lz < 32 && lz < self.avail {
                // Found a 1 within the valid window.
                zeros += lz;
                self.skip(lz + 1);
                return Ok(zeros);
            }
            zeros += lz;
            self.skip(lz);
        }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.consumed
    }

    pub fn remaining_bits(&self) -> u64 {
        (self.data.len() as u64) * 8 - self.consumed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;
    use crate::util::rng::Rng;

    #[test]
    fn single_byte_msb_first() {
        let mut w = BitWriter::new();
        w.write_bit(true);
        w.write_bit(false);
        w.write_bit(true);
        let buf = w.finish();
        assert_eq!(buf, vec![0b1010_0000]);
    }

    #[test]
    fn cross_byte_write() {
        let mut w = BitWriter::new();
        w.write_bits(0b1_1111_0000_1, 10); // 10 bits
        w.write_bits(0b01_1011, 6);
        let buf = w.finish();
        assert_eq!(buf.len(), 2);
        assert_eq!(buf, vec![0b1111_1000, 0b0101_1011]);
    }

    #[test]
    fn bit_len_tracks() {
        let mut w = BitWriter::new();
        w.write_bits(0, 7);
        w.write_bits(1, 11);
        assert_eq!(w.bit_len(), 18);
    }

    #[test]
    fn reader_roundtrip_fixed() {
        let mut w = BitWriter::new();
        let fields = [(0b101u64, 3u32), (0xFFFF, 16), (0, 1), (0x1ABCD, 17)];
        for &(v, n) in &fields {
            w.write_bits(v, n);
        }
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        for &(v, n) in &fields {
            assert_eq!(r.read_bits(n).unwrap() as u64, v);
        }
    }

    #[test]
    fn peek_does_not_consume() {
        let buf = [0b1100_0000u8];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.peek(2), 0b11);
        assert_eq!(r.peek(2), 0b11);
        r.skip(1);
        assert_eq!(r.peek(1), 1);
    }

    #[test]
    fn eof_detection() {
        let buf = [0xFFu8];
        let mut r = BitReader::new(&buf);
        assert!(r.read_bits(8).is_ok());
        assert_eq!(r.read_bits(1), Err(BitstreamEof));
    }

    #[test]
    fn peek_past_eof_zero_padded() {
        let buf = [0xFFu8];
        let mut r = BitReader::new(&buf);
        assert_eq!(r.peek(16), 0xFF00);
    }

    #[test]
    fn unary_basic() {
        let mut w = BitWriter::new();
        w.write_zeros(5);
        w.write_bit(true);
        w.write_zeros(0);
        w.write_bit(true);
        w.write_zeros(12);
        w.write_bit(true);
        let buf = w.finish();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_unary().unwrap(), 5);
        assert_eq!(r.read_unary().unwrap(), 0);
        assert_eq!(r.read_unary().unwrap(), 12);
    }

    #[test]
    fn unary_eof() {
        let buf = [0x00u8]; // all zeros, no terminating 1
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_unary(), Err(BitstreamEof));
    }

    #[test]
    fn unary_long_runs() {
        for zeros in [31u32, 32, 33, 63, 64, 65, 100] {
            let mut w = BitWriter::new();
            w.write_zeros(zeros);
            w.write_bit(true);
            let buf = w.finish();
            let mut r = BitReader::new(&buf);
            assert_eq!(r.read_unary().unwrap(), zeros, "zeros={zeros}");
        }
    }

    #[test]
    fn prop_roundtrip_random_fields() {
        prop::check("bitstream roundtrip", Default::default(), |rng, size| {
            let nfields = rng.below(size as u64 + 1) as usize;
            let fields: Vec<(u64, u32)> = (0..nfields)
                .map(|_| {
                    let n = 1 + rng.below(32) as u32;
                    let v = rng.next_u64() & ((1u64 << n) - 1);
                    (v, n)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, n) in &fields {
                w.write_bits(v, n);
            }
            let expect_bits: u64 = fields.iter().map(|&(_, n)| n as u64).sum();
            if w.bit_len() != expect_bits {
                return Err(format!("bit_len {} != {expect_bits}", w.bit_len()));
            }
            let buf = w.finish();
            if buf.len() as u64 != (expect_bits + 7) / 8 {
                return Err("buffer length mismatch".into());
            }
            let mut r = BitReader::new(&buf);
            for (i, &(v, n)) in fields.iter().enumerate() {
                let got = r.read_bits(n).map_err(|e| e.to_string())? as u64;
                if got != v {
                    return Err(format!("field {i}: got {got}, want {v}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_interleaved_unary_and_fixed() {
        prop::check("unary+fixed roundtrip", Default::default(), |rng, size| {
            #[derive(Debug)]
            enum F {
                Fixed(u64, u32),
                Unary(u32),
            }
            let n = rng.below(size as u64 / 8 + 2) as usize;
            let fields: Vec<F> = (0..n)
                .map(|_| {
                    if rng.uniform() < 0.5 {
                        let bits = 1 + rng.below(24) as u32;
                        F::Fixed(rng.next_u64() & ((1 << bits) - 1), bits)
                    } else {
                        F::Unary(rng.below(70) as u32)
                    }
                })
                .collect();
            let mut w = BitWriter::new();
            for f in &fields {
                match f {
                    F::Fixed(v, n) => w.write_bits(*v, *n),
                    F::Unary(z) => {
                        w.write_zeros(*z);
                        w.write_bit(true);
                    }
                }
            }
            let buf = w.finish();
            let mut r = BitReader::new(&buf);
            for f in &fields {
                match f {
                    F::Fixed(v, n) => {
                        let got = r.read_bits(*n).map_err(|e| e.to_string())?;
                        if got as u64 != *v {
                            return Err(format!("fixed: {got} != {v}"));
                        }
                    }
                    F::Unary(z) => {
                        let got = r.read_unary().map_err(|e| e.to_string())?;
                        if got != *z {
                            return Err(format!("unary: {got} != {z}"));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn drain_into_matches_finish_per_chunk() {
        // A reused writer drained chunk-by-chunk must produce exactly
        // the bytes of fresh writers finished per chunk.
        let chunks: [&[(u64, u32)]; 3] = [
            &[(0b101, 3), (0xFFFF, 16)],
            &[(0, 1)],
            &[(0x1ABCD, 17), (1, 1), (0, 7)],
        ];
        let mut reused = BitWriter::new();
        let mut drained = Vec::new();
        let mut finished = Vec::new();
        for fields in chunks {
            let mut fresh = BitWriter::new();
            for &(v, n) in fields {
                reused.write_bits(v, n);
                fresh.write_bits(v, n);
            }
            reused.drain_into(&mut drained);
            finished.extend_from_slice(&fresh.finish());
        }
        assert_eq!(drained, finished);
        assert_eq!(reused.bit_len(), 0, "drain must reset the bit count");
    }

    #[test]
    fn reset_clears_partial_byte() {
        let mut w = BitWriter::new();
        w.write_bits(0b1, 1);
        w.reset();
        w.write_bits(0b1010_1010, 8);
        assert_eq!(w.finish(), vec![0b1010_1010]);
    }

    #[test]
    fn rng_stream_bytes_roundtrip() {
        let mut rng = Rng::new(99);
        let mut data = vec![0u8; 1000];
        rng.fill_bytes(&mut data);
        let mut w = BitWriter::new();
        for &b in &data {
            w.write_bits(b as u64, 8);
        }
        assert_eq!(w.finish(), data);
    }
}
