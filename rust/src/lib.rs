//! # qlc — Quad Length Codes for lossless compression of e4m3
//!
//! A full reproduction stack for the paper *"Quad Length Codes for
//! Lossless Compression of e4m3"*: the QLC codec and every baseline and
//! substrate it is evaluated against.
//!
//! Layer map (DESIGN.md):
//! * [`formats`] — the e4m3 data type and block-32 quantizer;
//! * [`codecs`] — QLC, canonical Huffman, Elias γ/δ/ω, Exp-Golomb, raw;
//!   the batched decode kernel ([`codecs::kernel`]: `BitCursor` +
//!   `DecodeKernel`, word-at-a-time table/LZC decode), streaming
//!   sessions, the unified codec registry, and the chunked QLF2 frame
//!   container (parallel decode, optional adaptive per-chunk tables);
//! * [`stats`] — PMFs, entropy, compressibility;
//! * [`data`] — tensor/symbol generators calibrated to the paper's
//!   distributions;
//! * [`hw`] — cycle-level decoder hardware model (LUT vs tree);
//! * [`transport`] — chunk-granular transport layer: the pipelined-hop
//!   fabric simulator, the threaded bounded-channel backend, and the
//!   multi-host TCP backend (QWC1 wire frames + ring rendezvous);
//! * [`collective`] — bandwidth-bound collective ops with compression
//!   on the transport; [`collective::dist`] runs them across OS
//!   processes over sockets (`qlc worker` / `qlc launch`);
//! * [`coordinator`] — threaded leader/worker compression pipeline
//!   placing frame/shard descriptors on a worker pool;
//! * [`serve`] — the streaming compression service: an event-driven
//!   (epoll-backed) `qlc serve` server with per-connection codec
//!   sessions and bounded backpressure, its [`serve::ServeClient`]
//!   counterpart, and the `qlc loadgen` concurrent load generator;
//! * [`obs`] — dependency-free observability: atomic counter/histogram
//!   registry (p50/p90/p99, cross-rank merge), runtime-switched spans,
//!   Chrome-trace and Prometheus-text exporters (`--trace`/`--metrics`);
//! * `runtime` — PJRT executor for the AOT JAX/Pallas artifacts
//!   (feature `pjrt`; needs the `xla` + `anyhow` crates, see
//!   `Cargo.toml`);
//! * [`util`] — offline-environment substrates (RNG, JSON, CLI, bench,
//!   property testing);
//! * [`analysis`] — the `qlc analyze` invariant linter: a
//!   dependency-free static-analysis pass over this crate's own source
//!   (wire-format casts, cap-before-alloc, panic-free library paths,
//!   SAFETY-documented unsafe, forbidden constructs).

pub mod analysis;
pub mod bitstream;
pub mod codecs;
pub mod collective;
pub mod coordinator;
pub mod data;
pub mod formats;
pub mod hw;
pub mod obs;
pub mod report;
#[cfg(feature = "pjrt")]
pub mod runtime;
pub mod serve;
pub mod stats;
pub mod transport;
pub mod util;
