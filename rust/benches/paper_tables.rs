//! Regenerates **every table and figure** of the paper's evaluation
//! (DESIGN.md §5: FIG1–FIG7, TAB1–TAB4, plus the codec-comparison
//! summaries) at the full 18×64 shard grid, and times the regeneration
//! stages.  Output is recorded in EXPERIMENTS.md.

use std::time::Instant;

use qlc::report;
use qlc::util::bench::{smoke_config, Bencher};

fn main() {
    println!("=== paper_tables: full-grid regeneration (18 layers × 64 shards scale) ===");
    let t0 = Instant::now();
    // scale=2 → 9 layers × 32 shards = 288 shards/tensor-type at 32 Ki
    // symbols each (~9.4 M symbols per PMF): full-fidelity statistics
    // in bounded time.  QLC_BENCH_SMOKE=1 drops to scale=16 (CI smoke).
    let scale = qlc::util::bench::smoke_scaled(2, 16);
    let pmfs = report::paper_pmfs(42, scale);
    let shards = qlc::data::shards::ShardConfig::paper_scaled(scale);
    println!(
        "pmf construction (scale={scale}: {}×{} shards, calibrated): {:.2?}\n",
        shards.layers,
        shards.shards_per_layer,
        t0.elapsed()
    );

    for artifact in report::all_artifacts(&pmfs) {
        println!("{}", artifact.text);
    }

    // Timing of the table-construction stages themselves.
    let mut b = Bencher::with_config(smoke_config());
    let sorted1 = pmfs.ffn1.sorted_desc();
    b.bench("build: huffman codebook (FFN1 pmf)", || {
        let mut h = qlc::stats::Histogram::new();
        for i in 0..256 {
            h.counts[i] = (pmfs.ffn1.p[i] * 1.15e9) as u64 + 1;
        }
        std::hint::black_box(
            qlc::codecs::huffman::HuffmanCodec::from_histogram(&h),
        );
    });
    b.bench("build: qlc-t1 codec (FFN1 pmf)", || {
        std::hint::black_box(qlc::codecs::qlc::QlcCodec::from_pmf(
            qlc::codecs::qlc::AreaScheme::table1(),
            &pmfs.ffn1,
        ));
    });
    b.bench("build: scheme optimizer (FFN1 pmf, P=1..4)", || {
        std::hint::black_box(qlc::codecs::qlc::optimizer::optimize_scheme(
            &sorted1,
        ));
    });
}
