//! EXT-COLL: the paper's motivating claim — "lossless compression is an
//! effective way to reduce the network traffic and improve collective
//! performance".  Sweeps link bandwidth and codec over a ring
//! all-reduce and an all-gather on the simulated fabric, reporting the
//! modelled total time (network + measured codec) and the crossover
//! where codec cost outweighs wire savings.

use qlc::collective::{ring_allgather, ring_allreduce, Fabric, Transport};
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::Variant;
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

const WORKERS: usize = 8;
const ELEMS: usize = 1 << 20; // 1 Mi f32 per worker

fn main() {
    println!(
        "=== collective_bench: ring ops, {WORKERS} workers, {ELEMS} \
         elements/worker ==="
    );
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(1);
    let data: Vec<Vec<f32>> =
        (0..WORKERS).map(|_| gen.generate(&mut rng, ELEMS)).collect();
    let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 1 << 16));

    let transports = |codec: &str| -> Transport {
        if codec == "raw" {
            Transport::Raw
        } else {
            Transport::Compressed {
                codec: codec.into(),
                calibration: Box::new(cal.clone()),
            }
        }
    };

    // Network-only time is the hardware-codec scenario (the paper's
    // target: a wire-speed decoder); "sw total" adds our measured
    // software codec+quantize wall time — the honest crossover for a
    // software implementation.
    println!("\n-- allreduce: network time (ms) vs link bandwidth --");
    println!(
        "{:>8} {:>10} {:>10} {:>10} {:>9} {:>12}",
        "GB/s", "raw-net", "qlc-net", "huff-net", "speedup", "qlc-sw-total"
    );
    for gbps in [1.0, 5.0, 10.0, 25.0, 50.0, 100.0, 400.0] {
        let fabric = Fabric {
            workers: WORKERS,
            link_bandwidth: gbps * 1e9,
            link_latency: 2e-6,
        };
        let (_, raw) =
            ring_allreduce(&fabric, &data, &transports("raw")).unwrap();
        let (_, qlc) =
            ring_allreduce(&fabric, &data, &transports("qlc")).unwrap();
        let (_, huff) =
            ring_allreduce(&fabric, &data, &transports("huffman")).unwrap();
        println!(
            "{:>8.0} {:>10.3} {:>10.3} {:>10.3} {:>8.2}x {:>12.3}",
            gbps,
            raw.network_time_s * 1e3,
            qlc.network_time_s * 1e3,
            huff.network_time_s * 1e3,
            raw.network_time_s / qlc.network_time_s,
            qlc.total_time_s() * 1e3
        );
    }

    println!("\n-- allreduce: bytes on wire --");
    let fabric = Fabric::pod(WORKERS);
    for codec in ["raw", "qlc", "qlc-t1", "huffman", "elias-delta", "eg3"] {
        let (_, report) =
            ring_allreduce(&fabric, &data, &transports(codec)).unwrap();
        println!(
            "  {:<12} wire {:>12} B  ratio {:.3}  codec {:>8.3} ms",
            codec,
            report.wire_bytes,
            report.compression_ratio(),
            report.codec_time_s * 1e3
        );
    }

    println!("\n-- allgather (weight shards) --");
    let shards: Vec<Vec<u8>> = (0..WORKERS)
        .map(|_| {
            TensorGen::new(TensorKind::Weight, Variant::ExmY)
                .symbols(&mut rng, ELEMS / WORKERS)
        })
        .collect();
    let scales: Vec<Vec<f32>> = (0..WORKERS)
        .map(|_| vec![1.0; ELEMS / WORKERS / 32])
        .collect();
    let cal_w = Histogram::from_symbols(&shards.concat());
    for codec in ["raw", "qlc", "huffman"] {
        let transport = if codec == "raw" {
            Transport::Raw
        } else {
            Transport::Compressed {
                codec: codec.into(),
                calibration: Box::new(cal_w.clone()),
            }
        };
        let (_, report) =
            ring_allgather(&fabric, &shards, &scales, &transport).unwrap();
        println!(
            "  {:<12} wire {:>12} B  ratio {:.3}  total {:>8.3} ms",
            codec,
            report.wire_bytes,
            report.compression_ratio(),
            report.total_time_s() * 1e3
        );
    }

    println!("\n-- coordinator pipeline scaling (qlc, 16 Mi symbols) --");
    use qlc::coordinator::{Pipeline, PipelineConfig};
    let stream = gen.symbols(&mut rng, 16 << 20);
    let cal2 = Histogram::from_symbols(&stream[..1 << 16]);
    for workers in [1usize, 2, 4, 8] {
        let pipe = Pipeline::new(
            PipelineConfig {
                workers,
                chunk_size: 256 * 1024,
                queue_depth: workers * 2,
            },
            "qlc",
            &cal2,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let frames = pipe.compress_stream(&stream);
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  workers={workers}: {:>7.1} MB/s end-to-end ({} frames, {:.1}% compressibility)",
            stream.len() as f64 / wall / 1e6,
            frames.len(),
            pipe.metrics().compressibility() * 100.0
        );
    }
}
