//! EXT-COLL: the paper's motivating claim — "lossless compression is an
//! effective way to reduce the network traffic and improve collective
//! performance".  Sweeps link bandwidth and codec over a ring
//! all-reduce and an all-gather on the simulated fabric, reporting the
//! modelled total time (network + measured codec), the chunk-pipelined
//! wall time (decode of chunk k overlaps transfer of chunk k+1), and
//! the overlap savings — how much of the codec cost the transport
//! layer hides behind the wire.
//!
//! Reading the overlap columns: `serial` is wire + codec back-to-back,
//! `pipelined` is the transport recurrence, `hidden%` is
//! `1 - pipelined/serial`.  `pipelined ≤ serial` always holds (the
//! run asserts it); `hidden% → codec share` as links get slower.
//!
//! Set `QLC_BENCH_SMOKE=1` to run a reduced version (CI smoke).

use qlc::collective::{
    ring_allgather, ring_allreduce, ring_allreduce_with, Fabric, Transport,
};
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::Variant;
use qlc::stats::Histogram;
use qlc::util::bench::smoke_scaled;
use qlc::util::rng::Rng;

const WORKERS: usize = 8;

fn main() {
    let elems = smoke_scaled(1 << 20, 1 << 14); // f32 per worker
    println!(
        "=== collective_bench: ring ops, {WORKERS} workers, {elems} \
         elements/worker ==="
    );
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(1);
    let data: Vec<Vec<f32>> =
        (0..WORKERS).map(|_| gen.generate(&mut rng, elems)).collect();
    let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 1 << 16));

    let transports = |codec: &str| -> Transport {
        if codec == "raw" {
            Transport::Raw
        } else {
            Transport::Compressed {
                codec: codec.into(),
                calibration: Box::new(cal.clone()),
            }
        }
    };

    // Network-only time is the hardware-codec scenario (the paper's
    // target: a wire-speed decoder); "sw total" adds our measured
    // software codec+quantize wall time — the honest crossover for a
    // software implementation.  The pipelined column is the software
    // codec with chunk-granular overlap: what a streaming NIC path
    // actually pays.
    println!("\n-- allreduce: time (ms) vs link bandwidth, qlc transport --");
    println!(
        "{:>8} {:>10} {:>10} {:>12} {:>12} {:>8}",
        "GB/s", "raw-net", "qlc-net", "qlc-serial", "qlc-pipe", "hidden%"
    );
    let sweep: &[f64] = if qlc::util::bench::smoke() {
        &[5.0, 50.0]
    } else {
        &[1.0, 5.0, 10.0, 25.0, 50.0, 100.0, 400.0]
    };
    for &gbps in sweep {
        let fabric = Fabric {
            workers: WORKERS,
            link_bandwidth: gbps * 1e9,
            link_latency: 2e-6,
        };
        let (_, raw) =
            ring_allreduce(&fabric, &data, &transports("raw")).unwrap();
        let (_, qlc) =
            ring_allreduce(&fabric, &data, &transports("qlc")).unwrap();
        assert!(
            qlc.pipelined_time_s <= qlc.total_time_s() * (1.0 + 1e-9),
            "pipelined wall time must not exceed serial wall time"
        );
        println!(
            "{:>8.0} {:>10.3} {:>10.3} {:>12.3} {:>12.3} {:>7.1}%",
            gbps,
            raw.network_time_s * 1e3,
            qlc.network_time_s * 1e3,
            qlc.total_time_s() * 1e3,
            qlc.pipelined_time_s * 1e3,
            qlc.overlap_savings() * 100.0
        );
    }

    println!("\n-- allreduce: pipelined time vs transport chunk size --");
    println!(
        "{:>14} {:>12} {:>12} {:>8}",
        "chunk-symbols", "serial-ms", "pipe-ms", "hidden%"
    );
    let fabric = Fabric::ethernet(WORKERS); // slow links: codec visible
    for chunk in [usize::MAX, 64 * 1024, 16 * 1024, 4 * 1024] {
        let (_, rep) =
            ring_allreduce_with(&fabric, &data, &transports("qlc"), chunk)
                .unwrap();
        assert!(rep.pipelined_time_s <= rep.total_time_s() * (1.0 + 1e-9));
        let label = if chunk == usize::MAX {
            "whole".to_string()
        } else {
            format!("{chunk}")
        };
        println!(
            "{label:>14} {:>12.3} {:>12.3} {:>7.1}%",
            rep.total_time_s() * 1e3,
            rep.pipelined_time_s * 1e3,
            rep.overlap_savings() * 100.0
        );
    }

    println!("\n-- allreduce: bytes on wire --");
    let fabric = Fabric::pod(WORKERS);
    for codec in ["raw", "qlc", "qlc-t1", "huffman", "elias-delta", "eg3"] {
        let (_, report) =
            ring_allreduce(&fabric, &data, &transports(codec)).unwrap();
        println!(
            "  {:<12} wire {:>12} B  ratio {:.3}  codec {:>8.3} ms  \
             hidden {:>5.1}%",
            codec,
            report.wire_bytes,
            report.compression_ratio(),
            report.codec_time_s * 1e3,
            report.overlap_savings() * 100.0
        );
    }

    println!("\n-- allgather (weight shards) --");
    let shards: Vec<Vec<u8>> = (0..WORKERS)
        .map(|_| {
            TensorGen::new(TensorKind::Weight, Variant::ExmY)
                .symbols(&mut rng, elems / WORKERS)
        })
        .collect();
    let scales: Vec<Vec<f32>> = (0..WORKERS)
        .map(|_| vec![1.0; elems / WORKERS / 32])
        .collect();
    let cal_w = Histogram::from_symbols(&shards.concat());
    for codec in ["raw", "qlc", "huffman"] {
        let transport = if codec == "raw" {
            Transport::Raw
        } else {
            Transport::Compressed {
                codec: codec.into(),
                calibration: Box::new(cal_w.clone()),
            }
        };
        let (_, report) =
            ring_allgather(&fabric, &shards, &scales, &transport).unwrap();
        println!(
            "  {:<12} wire {:>12} B  ratio {:.3}  total {:>8.3} ms  \
             pipelined {:>8.3} ms",
            codec,
            report.wire_bytes,
            report.compression_ratio(),
            report.total_time_s() * 1e3,
            report.pipelined_time_s * 1e3
        );
    }

    println!("\n-- threaded engine: measured wall time vs chunking --");
    use qlc::collective::engine::threaded_allreduce_with;
    for (label, chunk) in
        [("whole-payload", usize::MAX), ("16Ki-chunks", 16 * 1024)]
    {
        let (_, rep) = threaded_allreduce_with(
            WORKERS,
            data.clone(),
            &transports("qlc"),
            chunk,
            2,
        )
        .unwrap();
        println!(
            "  {:<14} wall {:>7.1} ms  wire {:>12} B (of {} raw)",
            label,
            rep.wall_time_s * 1e3,
            rep.wire_bytes,
            rep.raw_bytes
        );
    }

    println!("\n-- TCP loopback ring (real sockets, 4 workers) --");
    use qlc::collective::dist::{
        round_size, run_local_ring, DistOp, WorkerConfig,
    };
    let tcp_elems = smoke_scaled(1 << 18, 1 << 12);
    for (label, op) in [
        ("allreduce", DistOp::Allreduce),
        ("allgather-shards", DistOp::AllgatherShards),
    ] {
        let mut cfg = WorkerConfig::new(0, 4, String::new());
        cfg.op = op;
        cfg.codec = "qlc".into();
        cfg.elems = round_size(tcp_elems, 4).unwrap();
        let outcomes = run_local_ring(&cfg).unwrap();
        for o in &outcomes[1..] {
            assert_eq!(o.checksum, outcomes[0].checksum, "{label}");
        }
        let r = &outcomes[0].report;
        assert!(
            r.pipelined_time_s <= r.total_time_s() * (1.0 + 1e-9),
            "{label}: measured pipelined wall must not exceed serial"
        );
        println!(
            "  {label:<18} wall {:>8.2} ms pipelined (serial est {:>8.2} \
             ms, {:>4.1}% hidden)  wire {:>10} B of {:>10} raw",
            r.pipelined_time_s * 1e3,
            r.total_time_s() * 1e3,
            r.overlap_savings() * 100.0,
            r.wire_bytes,
            r.raw_bytes
        );
    }

    let stream_n = smoke_scaled(16 << 20, 1 << 18);
    println!(
        "\n-- coordinator pipeline scaling (qlc, {stream_n} symbols) --"
    );
    use qlc::coordinator::{Pipeline, PipelineConfig};
    let stream = gen.symbols(&mut rng, stream_n);
    let cal2 = Histogram::from_symbols(&stream[..1 << 16]);
    for workers in [1usize, 2, 4, 8] {
        let pipe = Pipeline::new(
            PipelineConfig {
                workers,
                chunk_size: 256 * 1024,
                queue_depth: workers * 2,
            },
            "qlc",
            &cal2,
        )
        .unwrap();
        let t0 = std::time::Instant::now();
        let frames = pipe.compress_stream(&stream).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  workers={workers}: {:>7.1} MB/s end-to-end ({} frames, {:.1}% compressibility)",
            stream.len() as f64 / wall / 1e6,
            frames.len(),
            pipe.metrics().compressibility().unwrap_or(0.0) * 100.0
        );
    }

    println!("\n-- coordinator sharded manifests (qlc, 8 shards) --");
    let pipe = Pipeline::new(
        PipelineConfig { workers: 4, chunk_size: 256 * 1024, queue_depth: 8 },
        "qlc",
        &cal2,
    )
    .unwrap();
    let t0 = std::time::Instant::now();
    let (manifest, bodies) = pipe.compress_sharded(&stream, 8).unwrap();
    let wall = t0.elapsed().as_secs_f64();
    let total: usize = bodies.iter().map(|b| b.len()).sum();
    println!(
        "  {} shards, one {}-byte table header: {} -> {} bytes in {:.3}s \
         ({:.1} MB/s)",
        manifest.n_shards(),
        manifest.wire_header().len(),
        stream.len(),
        total,
        wall,
        stream.len() as f64 / wall / 1e6
    );
}
