//! Software encode/decode throughput for every codec on both paper
//! PMFs — the HEAD experiment's software half ("significantly speeds up
//! the decoding").  Also contrasts the two Huffman decoders (bit-serial
//! tree vs multi-level table), which is the software analogue of the
//! paper's hardware argument.

use qlc::bitstream::BitReader;
use qlc::codecs::frame::CodecSpec;
use qlc::codecs::huffman::decode::{TableDecoder, TreeDecoder};
use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::Codec;
use qlc::report;
use qlc::util::bench::Bencher;

const N: usize = 4 << 20; // 4 Mi symbols per stream

fn main() {
    println!("=== codec_throughput: {N} symbols per stream ===");
    let pmfs = report::paper_pmfs(42, 6);
    for (label, pmf, hist) in [
        ("ffn1", &pmfs.ffn1, &pmfs.ffn1_hist),
        ("ffn2", &pmfs.ffn2, &pmfs.ffn2_hist),
    ] {
        println!("--- {label} PMF (entropy {:.2} bits) ---", pmf.entropy());
        let symbols = report::sample_symbols(pmf, N, 7);
        let mut b = Bencher::new();

        for name in ["raw", "huffman", "qlc", "qlc-t1", "elias-gamma",
                     "elias-delta", "eg3"] {
            let spec = CodecSpec::by_name(name, hist).unwrap();
            let codec = spec.codec();
            let encoded = codec.encode_to_vec(&symbols);
            println!(
                "  {name}: {} -> {} bytes ({:.1}% compressibility)",
                symbols.len(),
                encoded.len(),
                (1.0 - encoded.len() as f64 / symbols.len() as f64) * 100.0
            );
            b.bench_bytes(&format!("{label}/encode/{name}"), N as u64, || {
                std::hint::black_box(codec.encode_to_vec(&symbols));
            });
            let mut out = Vec::with_capacity(N);
            b.bench_bytes(&format!("{label}/decode/{name}"), N as u64, || {
                out.clear();
                let mut r = BitReader::new(&encoded);
                codec.decode(&mut r, N, &mut out).unwrap();
                std::hint::black_box(out.len());
            });
        }

        // Huffman decoder micro-comparison: tree walk vs table.
        let huff = HuffmanCodec::from_histogram(hist);
        let encoded = huff.encode_to_vec(&symbols);
        let tree = TreeDecoder::new(huff.book());
        let table = TableDecoder::new(huff.book());
        let mut out = Vec::with_capacity(N);
        b.bench_bytes(&format!("{label}/decode/huffman-tree-serial"),
                      N as u64, || {
            out.clear();
            let mut r = BitReader::new(&encoded);
            tree.decode(&mut r, N, &mut out).unwrap();
            std::hint::black_box(out.len());
        });
        b.bench_bytes(&format!("{label}/decode/huffman-table"),
                      N as u64, || {
            out.clear();
            let mut r = BitReader::new(&encoded);
            table.decode(&mut r, N, &mut out).unwrap();
            std::hint::black_box(out.len());
        });
        println!();
    }
}
