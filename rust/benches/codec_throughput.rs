//! Software encode/decode throughput for every codec on both paper
//! PMFs — the HEAD experiment's software half ("significantly speeds up
//! the decoding").  Also contrasts the two Huffman decoders (bit-serial
//! tree vs multi-level table), the software analogue of the paper's
//! hardware argument; single-shot vs chunked-parallel QLF2 frame
//! decode; and — new with the decode kernel — the batched word-at-a-
//! time path vs the scalar one-symbol-per-step reference path for
//! every codec.
//!
//! New with the lane engine: a batched-vs-lanes section decodes the
//! same payload split into independent chunks, chunk-after-chunk vs
//! lane-interleaved lockstep.
//!
//! New with the encode kernel: the encode side now mirrors decode —
//! scalar `encode_scalar` (one `BitWriter::write_bits` per code) vs
//! batched `encode_batch` (staging-word [`BitSink`]), plus a
//! chunk-encode batched-vs-lanes section through [`LaneEncoder`].
//!
//! Under `QLC_BENCH_SMOKE=1` (the CI bench-smoke job) the
//! batched-vs-scalar sections (decode *and* encode) and the
//! lanes-vs-batched decode section are also *gates*: the process
//! exits non-zero if the batched QLC kernel moves fewer symbols/sec
//! than the scalar path in either direction, or lane decode drops
//! below batched (with a 10% noise floor — the two fast paths sit
//! much closer together than batched vs scalar).
//!
//! Every throughput number also lands in a machine-readable
//! `BENCH_8.json` (path overridable via `QLC_BENCH_JSON`), so the perf
//! trajectory is tracked run over run instead of living only in CI
//! logs.  New with the obs subsystem: every section's raw per-sample
//! timings are also folded through an [`qlc::obs`] log2 latency
//! histogram, and the JSON gains a `latency` array with p50/p90/p99
//! nanoseconds per section.

use qlc::bitstream::{BitReader, BitWriter};
use qlc::codecs::frame::{self, FrameOptions};
use qlc::codecs::huffman::decode::{TableDecoder, TreeDecoder};
use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::{
    BitCursor, BitSink, Codec, CodecRegistry, EncodeJob, EncodeKernel,
    LaneDecoder, LaneEncoder, LaneJob,
};
use qlc::obs;
use qlc::report;
use qlc::util::bench::{smoke_config, smoke_scaled, Bencher};
use qlc::util::json::Json;

fn main() {
    let n = smoke_scaled(4 << 20, 1 << 16); // symbols per stream
    let smoke = std::env::var("QLC_BENCH_SMOKE").is_ok();
    println!("=== codec_throughput: {n} symbols per stream ===");
    let registry = CodecRegistry::global();
    let pmfs = report::paper_pmfs(42, 6);
    let mut qlc_gate_failures = Vec::new();
    // Local registry (not the process-global one): these histograms
    // hold exactly this run's per-section sample timings.
    let reg = obs::Registry::new();
    let mut records: Vec<Json> = Vec::new();
    let mut record = |name: String, mbps: f64| {
        records.push(Json::obj().set("name", name.as_str()).set("mbps", mbps));
    };
    for (label, pmf, hist) in [
        ("ffn1", &pmfs.ffn1, &pmfs.ffn1_hist),
        ("ffn2", &pmfs.ffn2, &pmfs.ffn2_hist),
    ] {
        println!("--- {label} PMF (entropy {:.2} bits) ---", pmf.entropy());
        let symbols = report::sample_symbols(pmf, n, 7);
        let mut b = Bencher::with_config(smoke_config());

        // Encode + decode in both kernel modes.  Batched kernel vs
        // scalar reference: same tables, same bits.  On decode the
        // delta is one refill + word-at-a-time resolution per run of
        // codes vs per-symbol refill/EOF checks; on encode it is one
        // staging-word insert per code (quad-packed for QLC) vs a
        // `write_bits` shift-and-flush per code.  This is the software
        // form of the paper's speed claim, now in both directions.
        println!("  [batched = DecodeKernel/BitCursor + EncodeKernel/BitSink, scalar = per-symbol reference]");
        for name in ["raw", "huffman", "qlc", "qlc-t1", "elias-gamma",
                     "elias-delta", "eg3"] {
            let handle = registry.resolve(name, hist).unwrap();
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);
            println!(
                "  {name}: {} -> {} bytes ({:.1}% compressibility)",
                symbols.len(),
                encoded.len(),
                (1.0 - encoded.len() as f64 / symbols.len() as f64) * 100.0
            );
            let enc_scalar_tp = b
                .bench_bytes(
                    &format!("{label}/encode-scalar/{name}"),
                    n as u64,
                    || {
                        let mut w = BitWriter::with_capacity(symbols.len());
                        codec.encode_scalar(&symbols, &mut w);
                        std::hint::black_box(w.finish().len());
                    },
                )
                .throughput_mbps();
            let enc_batched_tp = b
                .bench_bytes(
                    &format!("{label}/encode-batched/{name}"),
                    n as u64,
                    || {
                        let mut sink = BitSink::with_capacity(symbols.len());
                        codec.encode_batch(&symbols, &mut sink);
                        std::hint::black_box(sink.finish().len());
                    },
                )
                .throughput_mbps();
            println!(
                "  {name}: encode batched/scalar = {:.2}x ({:.1} vs {:.1} \
                 MB/s)",
                enc_batched_tp / enc_scalar_tp,
                enc_batched_tp,
                enc_scalar_tp
            );
            record(format!("{label}/encode-scalar/{name}"), enc_scalar_tp);
            record(format!("{label}/encode-batched/{name}"), enc_batched_tp);
            if name == "qlc" && enc_batched_tp < enc_scalar_tp {
                qlc_gate_failures.push(format!(
                    "{label}: encode batched {enc_batched_tp:.1} MB/s < \
                     scalar {enc_scalar_tp:.1} MB/s"
                ));
            }
            let mut out = vec![0u8; n];
            let scalar_tp = b
                .bench_bytes(
                    &format!("{label}/decode-scalar/{name}"),
                    n as u64,
                    || {
                        let mut r = BitReader::new(&encoded);
                        codec.decode_scalar_into(&mut r, &mut out).unwrap();
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            let batched_tp = b
                .bench_bytes(
                    &format!("{label}/decode-batched/{name}"),
                    n as u64,
                    || {
                        let mut cur = BitCursor::new(&encoded);
                        codec.decode_into(&mut cur, &mut out).unwrap();
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            println!(
                "  {name}: batched/scalar = {:.2}x ({:.1} vs {:.1} MB/s)",
                batched_tp / scalar_tp,
                batched_tp,
                scalar_tp
            );
            record(format!("{label}/decode-scalar/{name}"), scalar_tp);
            record(format!("{label}/decode-batched/{name}"), batched_tp);
            if name == "qlc" && batched_tp < scalar_tp {
                qlc_gate_failures.push(format!(
                    "{label}: batched {batched_tp:.1} MB/s < scalar \
                     {scalar_tp:.1} MB/s"
                ));
            }
        }

        // Batched vs lanes: the same payload split into independent
        // chunks (the QLF2/transport unit), decoded chunk-after-chunk
        // through one cursor vs lane-interleaved lockstep over 4/8
        // cursors — and, mirrored, encoded chunk-after-chunk through
        // one sink vs lane-interleaved lockstep over 4/8 sinks.  Same
        // tables, same bits — the delta is purely the ILP of
        // overlapping independent table-lookup chains.
        let lane_engine = LaneDecoder::auto();
        let lane_encoder = LaneEncoder::auto();
        println!(
            "  [lanes = LaneDecoder/LaneEncoder x{} lockstep over \
             independent chunks]",
            lane_engine.lanes()
        );
        let chunk_sym = (n / 64).max(1);
        for name in ["qlc", "huffman", "elias-gamma"] {
            let handle = registry.resolve(name, hist).unwrap();
            let codec = handle.codec();
            let payloads: Vec<Vec<u8>> = symbols
                .chunks(chunk_sym)
                .map(|c| codec.encode_to_vec(c))
                .collect();
            let mut out = vec![0u8; n];
            let chunks_batched_tp = b
                .bench_bytes(
                    &format!("{label}/decode-chunks-batched/{name}"),
                    n as u64,
                    || {
                        for (payload, dst) in
                            payloads.iter().zip(out.chunks_mut(chunk_sym))
                        {
                            let mut cur = BitCursor::new(payload);
                            codec.decode_into(&mut cur, dst).unwrap();
                        }
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            let lanes_tp = b
                .bench_bytes(
                    &format!("{label}/decode-chunks-lanes/{name}"),
                    n as u64,
                    || {
                        let mut jobs: Vec<LaneJob> = payloads
                            .iter()
                            .zip(out.chunks_mut(chunk_sym))
                            .map(|(p, o)| LaneJob { payload: p, out: o })
                            .collect();
                        lane_engine.decode_jobs(codec, &mut jobs).unwrap();
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            println!(
                "  {name}: lanes/batched = {:.2}x ({:.1} vs {:.1} MB/s)",
                lanes_tp / chunks_batched_tp,
                lanes_tp,
                chunks_batched_tp
            );
            record(
                format!("{label}/decode-chunks-batched/{name}"),
                chunks_batched_tp,
            );
            record(format!("{label}/decode-chunks-lanes/{name}"), lanes_tp);
            // Encode mirror: same chunks, one reused sink
            // chunk-after-chunk vs lane-interleaved sinks.
            let enc_chunks_batched_tp = b
                .bench_bytes(
                    &format!("{label}/encode-chunks-batched/{name}"),
                    n as u64,
                    || {
                        let mut sink = BitSink::with_capacity(chunk_sym);
                        let mut buf = Vec::new();
                        for chunk in symbols.chunks(chunk_sym) {
                            codec.encode_batch(chunk, &mut sink);
                            sink.drain_into(&mut buf);
                        }
                        std::hint::black_box(buf.len());
                    },
                )
                .throughput_mbps();
            let mut lane_outs: Vec<Vec<u8>> =
                vec![Vec::new(); payloads.len()];
            let enc_chunks_lanes_tp = b
                .bench_bytes(
                    &format!("{label}/encode-chunks-lanes/{name}"),
                    n as u64,
                    || {
                        for o in lane_outs.iter_mut() {
                            o.clear();
                        }
                        let mut jobs: Vec<EncodeJob> = symbols
                            .chunks(chunk_sym)
                            .zip(lane_outs.iter_mut())
                            .map(|(c, o)| EncodeJob { symbols: c, out: o })
                            .collect();
                        lane_encoder.encode_jobs(codec, &mut jobs);
                        std::hint::black_box(
                            lane_outs.iter().map(Vec::len).sum::<usize>(),
                        );
                    },
                )
                .throughput_mbps();
            println!(
                "  {name}: encode lanes/batched = {:.2}x ({:.1} vs {:.1} \
                 MB/s)",
                enc_chunks_lanes_tp / enc_chunks_batched_tp,
                enc_chunks_lanes_tp,
                enc_chunks_batched_tp
            );
            record(
                format!("{label}/encode-chunks-batched/{name}"),
                enc_chunks_batched_tp,
            );
            record(
                format!("{label}/encode-chunks-lanes/{name}"),
                enc_chunks_lanes_tp,
            );
            // Gate with a 10% noise floor: unlike batched-vs-scalar
            // (a ~2x structural gap), lanes-vs-batched compares two
            // close fast paths, and a shared CI runner can wobble a
            // single measurement a few percent.  A genuine lane
            // regression (losing the ILP win entirely) lands well
            // below the floor.
            if name == "qlc" && lanes_tp < 0.9 * chunks_batched_tp {
                qlc_gate_failures.push(format!(
                    "{label}: lanes {lanes_tp:.1} MB/s < batched \
                     {chunks_batched_tp:.1} MB/s"
                ));
            }
        }

        // Huffman decoder micro-comparison: tree walk vs table.
        let huff = HuffmanCodec::from_histogram(hist);
        let encoded = huff.encode_to_vec(&symbols);
        let tree = TreeDecoder::new(huff.book());
        let table = TableDecoder::new(huff.book());
        let mut out = vec![0u8; n];
        let tree_tp = b
            .bench_bytes(&format!("{label}/decode/huffman-tree-serial"),
                         n as u64, || {
                let mut r = BitReader::new(&encoded);
                tree.decode_into(&mut r, &mut out).unwrap();
                std::hint::black_box(out.len());
            })
            .throughput_mbps();
        record(format!("{label}/decode/huffman-tree-serial"), tree_tp);
        let table_tp = b
            .bench_bytes(&format!("{label}/decode/huffman-table"),
                         n as u64, || {
                let mut r = BitReader::new(&encoded);
                table.decode_into(&mut r, &mut out).unwrap();
                std::hint::black_box(out.len());
            })
            .throughput_mbps();
        record(format!("{label}/decode/huffman-table"), table_tp);

        // QLF2 frame path: single-shot (one chunk, serial) vs
        // chunked-parallel (64 Ki-symbol chunks, one worker per core).
        // Same tables, same payload bits — the delta is the chunked
        // format's parallel decode.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        println!("  [chunked-parallel uses {cores} worker threads]");
        for name in ["qlc", "huffman"] {
            let handle = registry.resolve(name, hist).unwrap();
            let single = frame::compress_with(
                &handle,
                &symbols,
                &FrameOptions {
                    chunk_symbols: usize::MAX,
                    threads: 1,
                    ..Default::default()
                },
            )
            .unwrap();
            let chunked =
                frame::compress_with(&handle, &symbols, &FrameOptions::default())
                    .unwrap();
            let tp = b
                .bench_bytes(
                    &format!("{label}/frame-decode/{name}/single-shot"),
                    n as u64,
                    || {
                        let out = frame::decompress_with(
                            &single,
                            &FrameOptions::serial(),
                        )
                        .unwrap();
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            record(format!("{label}/frame-decode/{name}/single-shot"), tp);
            let tp = b
                .bench_bytes(
                    &format!("{label}/frame-decode/{name}/chunked-parallel"),
                    n as u64,
                    || {
                        let out = frame::decompress(&chunked).unwrap();
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            record(
                format!("{label}/frame-decode/{name}/chunked-parallel"),
                tp,
            );
            let tp = b
                .bench_bytes(
                    &format!("{label}/frame-encode/{name}/chunked-parallel"),
                    n as u64,
                    || {
                        std::hint::black_box(
                            frame::compress(&handle, &symbols).unwrap().len(),
                        );
                    },
                )
                .throughput_mbps();
            record(
                format!("{label}/frame-encode/{name}/chunked-parallel"),
                tp,
            );
        }

        // Sharded manifests: N QLS1 shards sharing one table header
        // via QLM1 — the placement-unit analogue of the chunked frame.
        // Same tables, same payload bits; the delta vs single-frame is
        // per-shard framing only, and decode fans out across shards.
        let n_shards = 8;
        let handle = registry.resolve("qlc", hist).unwrap();
        let (manifest, shards) = frame::compress_sharded(
            &handle,
            &symbols,
            n_shards,
            &FrameOptions::default(),
        )
        .unwrap();
        let sharded_bytes: usize =
            manifest.to_bytes().len() + shards.iter().map(Vec::len).sum::<usize>();
        println!(
            "  qlc sharded x{n_shards}: {} bytes (one {}-byte header via \
             manifest)",
            sharded_bytes,
            manifest.wire_header().len()
        );
        let tp = b
            .bench_bytes(
                &format!("{label}/sharded-encode/qlc/x{n_shards}"),
                n as u64,
                || {
                    let (m, s) = frame::compress_sharded(
                        &handle,
                        &symbols,
                        n_shards,
                        &FrameOptions::default(),
                    )
                    .unwrap();
                    std::hint::black_box((m.n_shards(), s.len()));
                },
            )
            .throughput_mbps();
        record(format!("{label}/sharded-encode/qlc/x{n_shards}"), tp);
        let tp = b
            .bench_bytes(
                &format!("{label}/sharded-decode/qlc/x{n_shards}"),
                n as u64,
                || {
                    let out = frame::decompress_sharded(
                        &manifest,
                        &shards,
                        &FrameOptions::default(),
                    )
                    .unwrap();
                    std::hint::black_box(out.len());
                },
            )
            .throughput_mbps();
        record(format!("{label}/sharded-decode/qlc/x{n_shards}"), tp);
        // Fold this label's raw per-sample timings through the obs
        // latency histograms (one per section) for the JSON quantile
        // summary below.
        for r in b.results() {
            let h = reg
                .hist(&obs::label("bench_ns", &[("section", &r.name)]));
            for s in &r.samples {
                h.record(u64::try_from(s.as_nanos()).unwrap_or(u64::MAX));
            }
        }
        println!();
    }

    // Machine-readable perf record: every throughput number from this
    // run, plus the gate verdicts, so the perf trajectory can be
    // tracked across commits instead of re-read from CI logs.
    let out_path = std::env::var("QLC_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_8.json".to_string());
    // Per-section latency quantiles from the obs histograms: p50/p90/
    // p99 of the raw sample timings (log2-bucket upper edges, ns).
    let snap = reg.snapshot();
    let latency: Vec<Json> = snap
        .hists
        .iter()
        .map(|(key, h)| {
            Json::obj()
                .set("metric", key.as_str())
                .set("samples", h.count as usize)
                .set("p50_ns", h.quantile(0.5).unwrap_or(0) as usize)
                .set("p90_ns", h.quantile(0.9).unwrap_or(0) as usize)
                .set("p99_ns", h.quantile(0.99).unwrap_or(0) as usize)
        })
        .collect();
    let doc = Json::obj()
        .set("bench", "codec_throughput")
        .set("symbols_per_stream", n)
        .set("smoke", smoke)
        .set("lane_width", LaneDecoder::auto().lanes())
        .set("results", Json::Arr(records))
        .set("latency", Json::Arr(latency))
        .set(
            "gate_failures",
            Json::Arr(
                qlc_gate_failures
                    .iter()
                    .map(|s| Json::Str(s.clone()))
                    .collect(),
            ),
        );
    match std::fs::write(&out_path, doc.to_string_pretty()) {
        Ok(()) => println!("wrote {out_path}"),
        Err(e) => eprintln!("warning: cannot write {out_path}: {e}"),
    }

    if !qlc_gate_failures.is_empty() {
        eprintln!(
            "FAIL: QLC perf gates (decode: batched ≥ scalar, lanes ≥ batched; \
             encode: batched ≥ scalar):\n  {}",
            qlc_gate_failures.join("\n  ")
        );
        if smoke {
            std::process::exit(1);
        }
    }
}
