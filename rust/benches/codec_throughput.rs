//! Software encode/decode throughput for every codec on both paper
//! PMFs — the HEAD experiment's software half ("significantly speeds up
//! the decoding").  Also contrasts the two Huffman decoders (bit-serial
//! tree vs multi-level table), the software analogue of the paper's
//! hardware argument; single-shot vs chunked-parallel QLF2 frame
//! decode; and — new with the decode kernel — the batched word-at-a-
//! time path vs the scalar one-symbol-per-step reference path for
//! every codec.
//!
//! Under `QLC_BENCH_SMOKE=1` (the CI bench-smoke job) the
//! batched-vs-scalar section is also a *gate*: the process exits
//! non-zero if the batched QLC kernel decodes fewer symbols/sec than
//! the scalar path.

use qlc::bitstream::BitReader;
use qlc::codecs::frame::{self, FrameOptions};
use qlc::codecs::huffman::decode::{TableDecoder, TreeDecoder};
use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::{BitCursor, Codec, CodecRegistry};
use qlc::report;
use qlc::util::bench::{smoke_config, smoke_scaled, Bencher};

fn main() {
    let n = smoke_scaled(4 << 20, 1 << 16); // symbols per stream
    let smoke = std::env::var("QLC_BENCH_SMOKE").is_ok();
    println!("=== codec_throughput: {n} symbols per stream ===");
    let registry = CodecRegistry::global();
    let pmfs = report::paper_pmfs(42, 6);
    let mut qlc_gate_failures = Vec::new();
    for (label, pmf, hist) in [
        ("ffn1", &pmfs.ffn1, &pmfs.ffn1_hist),
        ("ffn2", &pmfs.ffn2, &pmfs.ffn2_hist),
    ] {
        println!("--- {label} PMF (entropy {:.2} bits) ---", pmf.entropy());
        let symbols = report::sample_symbols(pmf, n, 7);
        let mut b = Bencher::with_config(smoke_config());

        // Encode throughput + decode in both kernel modes.  Batched
        // kernel vs scalar reference: same tables, same bits; the
        // delta is one refill + word-at-a-time resolution per run of
        // codes vs per-symbol refill/EOF checks.  This is the software
        // form of the paper's decode-speed claim.
        println!("  [batched = DecodeKernel/BitCursor, scalar = decode_one per symbol]");
        for name in ["raw", "huffman", "qlc", "qlc-t1", "elias-gamma",
                     "elias-delta", "eg3"] {
            let handle = registry.resolve(name, hist).unwrap();
            let codec = handle.codec();
            let encoded = codec.encode_to_vec(&symbols);
            println!(
                "  {name}: {} -> {} bytes ({:.1}% compressibility)",
                symbols.len(),
                encoded.len(),
                (1.0 - encoded.len() as f64 / symbols.len() as f64) * 100.0
            );
            b.bench_bytes(&format!("{label}/encode/{name}"), n as u64, || {
                std::hint::black_box(codec.encode_to_vec(&symbols));
            });
            let mut out = vec![0u8; n];
            let scalar_tp = b
                .bench_bytes(
                    &format!("{label}/decode-scalar/{name}"),
                    n as u64,
                    || {
                        let mut r = BitReader::new(&encoded);
                        codec.decode_scalar_into(&mut r, &mut out).unwrap();
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            let batched_tp = b
                .bench_bytes(
                    &format!("{label}/decode-batched/{name}"),
                    n as u64,
                    || {
                        let mut cur = BitCursor::new(&encoded);
                        codec.decode_into(&mut cur, &mut out).unwrap();
                        std::hint::black_box(out.len());
                    },
                )
                .throughput_mbps();
            println!(
                "  {name}: batched/scalar = {:.2}x ({:.1} vs {:.1} MB/s)",
                batched_tp / scalar_tp,
                batched_tp,
                scalar_tp
            );
            if name == "qlc" && batched_tp < scalar_tp {
                qlc_gate_failures.push(format!(
                    "{label}: batched {batched_tp:.1} MB/s < scalar \
                     {scalar_tp:.1} MB/s"
                ));
            }
        }

        // Huffman decoder micro-comparison: tree walk vs table.
        let huff = HuffmanCodec::from_histogram(hist);
        let encoded = huff.encode_to_vec(&symbols);
        let tree = TreeDecoder::new(huff.book());
        let table = TableDecoder::new(huff.book());
        let mut out = vec![0u8; n];
        b.bench_bytes(&format!("{label}/decode/huffman-tree-serial"),
                      n as u64, || {
            let mut r = BitReader::new(&encoded);
            tree.decode_into(&mut r, &mut out).unwrap();
            std::hint::black_box(out.len());
        });
        b.bench_bytes(&format!("{label}/decode/huffman-table"),
                      n as u64, || {
            let mut r = BitReader::new(&encoded);
            table.decode_into(&mut r, &mut out).unwrap();
            std::hint::black_box(out.len());
        });

        // QLF2 frame path: single-shot (one chunk, serial) vs
        // chunked-parallel (64 Ki-symbol chunks, one worker per core).
        // Same tables, same payload bits — the delta is the chunked
        // format's parallel decode.
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        println!("  [chunked-parallel uses {cores} worker threads]");
        for name in ["qlc", "huffman"] {
            let handle = registry.resolve(name, hist).unwrap();
            let single = frame::compress_with(
                &handle,
                &symbols,
                &FrameOptions {
                    chunk_symbols: usize::MAX,
                    threads: 1,
                    ..Default::default()
                },
            );
            let chunked =
                frame::compress_with(&handle, &symbols, &FrameOptions::default());
            b.bench_bytes(
                &format!("{label}/frame-decode/{name}/single-shot"),
                n as u64,
                || {
                    let out = frame::decompress_with(
                        &single,
                        &FrameOptions::serial(),
                    )
                    .unwrap();
                    std::hint::black_box(out.len());
                },
            );
            b.bench_bytes(
                &format!("{label}/frame-decode/{name}/chunked-parallel"),
                n as u64,
                || {
                    let out = frame::decompress(&chunked).unwrap();
                    std::hint::black_box(out.len());
                },
            );
            b.bench_bytes(
                &format!("{label}/frame-encode/{name}/chunked-parallel"),
                n as u64,
                || {
                    std::hint::black_box(
                        frame::compress(&handle, &symbols).len(),
                    );
                },
            );
        }

        // Sharded manifests: N QLS1 shards sharing one table header
        // via QLM1 — the placement-unit analogue of the chunked frame.
        // Same tables, same payload bits; the delta vs single-frame is
        // per-shard framing only, and decode fans out across shards.
        let n_shards = 8;
        let handle = registry.resolve("qlc", hist).unwrap();
        let (manifest, shards) = frame::compress_sharded(
            &handle,
            &symbols,
            n_shards,
            &FrameOptions::default(),
        );
        let sharded_bytes: usize =
            manifest.to_bytes().len() + shards.iter().map(Vec::len).sum::<usize>();
        println!(
            "  qlc sharded x{n_shards}: {} bytes (one {}-byte header via \
             manifest)",
            sharded_bytes,
            manifest.wire_header().len()
        );
        b.bench_bytes(
            &format!("{label}/sharded-encode/qlc/x{n_shards}"),
            n as u64,
            || {
                let (m, s) = frame::compress_sharded(
                    &handle,
                    &symbols,
                    n_shards,
                    &FrameOptions::default(),
                );
                std::hint::black_box((m.n_shards(), s.len()));
            },
        );
        b.bench_bytes(
            &format!("{label}/sharded-decode/qlc/x{n_shards}"),
            n as u64,
            || {
                let out = frame::decompress_sharded(
                    &manifest,
                    &shards,
                    &FrameOptions::default(),
                )
                .unwrap();
                std::hint::black_box(out.len());
            },
        );
        println!();
    }

    if !qlc_gate_failures.is_empty() {
        eprintln!(
            "FAIL: batched QLC decode slower than scalar:\n  {}",
            qlc_gate_failures.join("\n  ")
        );
        if smoke {
            std::process::exit(1);
        }
    }
}
