//! HEAD (hardware half): the decoder hardware cost model on real
//! encoded streams of both paper PMFs — cycles/symbol, storage bits,
//! and critical-path stages for the bit-serial Huffman FSM, the
//! multi-level-table Huffman decoder, and the 2-stage QLC decoder.

use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::qlc::{AreaScheme, QlcCodec};
use qlc::hw;
use qlc::report;
use qlc::util::bench::{smoke_config, smoke_scaled, Bencher};

fn main() {
    // QLC_BENCH_SMOKE=1 shrinks the streams (CI smoke).
    let n = smoke_scaled(1 << 20, 1 << 15);
    println!("=== hw_model_bench: {n} symbols per stream ===");
    let pmfs = report::paper_pmfs(42, 6);
    let mut b = Bencher::with_config(smoke_config());
    for (label, pmf, hist, scheme) in [
        ("ffn1", &pmfs.ffn1, &pmfs.ffn1_hist, AreaScheme::table1()),
        ("ffn2", &pmfs.ffn2, &pmfs.ffn2_hist, AreaScheme::table2()),
    ] {
        let symbols = report::sample_symbols(pmf, n, 3);
        let huff = HuffmanCodec::from_histogram(hist);
        let qlc_codec = QlcCodec::from_pmf(scheme, pmf);
        let reports = hw::compare_on_stream(huff.book(), &qlc_codec, &symbols);
        println!(
            "--- {label}: huffman lengths {}–{} bits ---",
            huff.min_length(),
            huff.max_length()
        );
        for r in &reports {
            println!(
                "  {:<16} {:>7.3} cycles/sym  {:>9} storage bits  {:>2} \
                 worst stages",
                r.model,
                r.cycles_per_symbol(),
                r.storage_bits,
                r.worst_stages
            );
        }
        println!(
            "  QLC decode speedup vs bit-serial Huffman: {:.2}x",
            hw::qlc_speedup_vs_serial(&reports)
        );
        // Multi-lane QLC decoders (the paper's "not bit sequential"
        // advantage, scaled out).
        for lanes in [2u32, 4, 8] {
            let r = hw::ParallelQlcModel::new(&qlc_codec, lanes)
                .simulate(&symbols);
            println!(
                "  {:<16} {:>7.3} cycles/sym  {:>9} storage bits  {:>2} \
                 worst stages",
                r.model,
                r.cycles_per_symbol(),
                r.storage_bits,
                r.worst_stages
            );
        }
        // Encoder side (paper ref [12] context): both single-stage,
        // differing in LUT width / shifter width.
        for enc in [
            hw::EncoderModel::huffman(huff.book()),
            hw::EncoderModel::qlc(&qlc_codec),
        ] {
            println!(
                "  {:<16} 1 stage, LUT {:>6} bits, {}-bit shifter",
                enc.name,
                enc.storage_bits(),
                enc.shifter_width_bits()
            );
        }
        // Model-evaluation cost itself (for completeness).
        b.bench(&format!("{label}/simulate-serial-model"), || {
            std::hint::black_box(
                hw::HuffmanSerialModel::new(huff.book()).simulate(&symbols),
            );
        });
        println!();
    }
}
