//! EXT-OPT: ablations over the QLC design space (the paper's §8 future
//! work):
//!   1. hand schemes (T1/T2) vs the DP-optimized scheme, per PMF;
//!   2. prefix width P ∈ 1..=4;
//!   3. sensitivity sweep: compressibility vs distribution entropy;
//!   4. ranked universal codes (the "LUT + universal" hybrid) vs QLC —
//!      quantifying how much of QLC's win is the LUT and how much is
//!      the area structure.

use qlc::codecs::adaptive::{self, AdaptiveConfig};
use qlc::codecs::elias::{EliasCodec, EliasKind};
use qlc::codecs::expgolomb::ExpGolombCodec;
use qlc::codecs::huffman::HuffmanCodec;
use qlc::codecs::qlc::{optimizer, AreaScheme};
use qlc::codecs::Codec;
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::Variant;
#[cfg(feature = "zstd")]
use qlc::codecs::zstd_baseline;
use qlc::formats::{ExmyFormat, ExmySpec};
use qlc::report;
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

fn main() {
    // QLC_BENCH_SMOKE=1 shrinks the sampled streams (CI smoke).
    let n = qlc::util::bench::smoke_scaled(1 << 20, 1 << 15);
    let pmfs = report::paper_pmfs(42, 6);

    println!("=== ablation 1+2: scheme structure per PMF ===");
    for (label, pmf) in [("ffn1", &pmfs.ffn1), ("ffn2", &pmfs.ffn2)] {
        let sorted = pmf.sorted_desc();
        println!(
            "--- {label}: entropy {:.3}, ideal {:.1}% ---",
            pmf.entropy(),
            pmf.ideal_compressibility() * 100.0
        );
        println!(
            "  table1        {:>6.2}%",
            AreaScheme::table1().compressibility_sorted(&sorted) * 100.0
        );
        println!(
            "  table2        {:>6.2}%",
            AreaScheme::table2().compressibility_sorted(&sorted) * 100.0
        );
        for p in 1..=4u32 {
            let s = optimizer::optimize_for_prefix(&sorted, p);
            println!(
                "  opt P={p}       {:>6.2}%  (lengths {:?}, slack {})",
                s.compressibility_sorted(&sorted) * 100.0,
                s.distinct_lengths(),
                s.slack_code_points()
            );
        }
    }

    println!("\n=== ablation 3: compressibility vs entropy (FFN1 family) ===");
    println!(
        "{:>8} {:>9} {:>9} {:>9} {:>9}",
        "entropy", "ideal%", "huffman%", "qlc-t1%", "qlc-opt%"
    );
    for knob in [0.05f64, 0.2, 0.4, 0.55, 0.8, 1.1, 1.5] {
        let gen =
            TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY).with_knob(knob);
        let mut rng = Rng::new(11);
        let symbols = gen.symbols(&mut rng, n);
        let hist = Histogram::from_symbols(&symbols);
        let pmf = hist.pmf();
        let sorted = pmf.sorted_desc();
        let huff = HuffmanCodec::from_histogram(&hist);
        let opt = optimizer::optimize_scheme(&sorted);
        println!(
            "{:>8.3} {:>9.2} {:>9.2} {:>9.2} {:>9.2}",
            pmf.entropy(),
            pmf.ideal_compressibility() * 100.0,
            pmf.compressibility(&huff.code_lengths()) * 100.0,
            AreaScheme::table1().compressibility_sorted(&sorted) * 100.0,
            opt.compressibility_sorted(&sorted) * 100.0,
        );
    }

    println!("\n=== ablation 4: ranked universal codes vs QLC (FFN1 pmf) ===");
    let pmf = &pmfs.ffn1;
    let rank = pmf.rank_order();
    let sorted = pmf.sorted_desc();
    let rows: Vec<(String, f64)> = vec![
        (
            "elias-gamma (unranked)".into(),
            pmf.compressibility(&EliasCodec::new(EliasKind::Gamma).code_lengths()),
        ),
        (
            "elias-gamma-ranked".into(),
            pmf.compressibility(
                &EliasCodec::with_ranking(EliasKind::Gamma, &rank).code_lengths(),
            ),
        ),
        (
            "elias-delta-ranked".into(),
            pmf.compressibility(
                &EliasCodec::with_ranking(EliasKind::Delta, &rank).code_lengths(),
            ),
        ),
        (
            "eg3-ranked".into(),
            pmf.compressibility(
                &ExpGolombCodec::with_ranking(3, &rank).code_lengths(),
            ),
        ),
        (
            "eg5-ranked".into(),
            pmf.compressibility(
                &ExpGolombCodec::with_ranking(5, &rank).code_lengths(),
            ),
        ),
        (
            "qlc-t1".into(),
            AreaScheme::table1().compressibility_sorted(&sorted),
        ),
        (
            "qlc-opt".into(),
            optimizer::optimize_scheme(&sorted).compressibility_sorted(&sorted),
        ),
    ];
    for (name, c) in rows {
        println!("  {name:<26} {:>7.2}%", c * 100.0);
    }


    println!("\n=== ablation 5: cross-format sweep (Gaussian tensor, block-32) ===");
    println!("{:>8} {:>9} {:>9} {:>9}", "format", "entropy", "ideal%", "qlc-opt%");
    let mut rng = Rng::new(17);
    let mut data = vec![0f32; n];
    rng.fill_normal_f32(&mut data, 0.0, 1.0);
    for spec in [ExmySpec::E2M5, ExmySpec::E3M4, ExmySpec::E4M3,
                 ExmySpec::E5M2] {
        let f = ExmyFormat::new(spec);
        let (symbols, _) = f.quantize_blocks(&data);
        let pmf = Histogram::from_symbols(&symbols).pmf();
        let sorted = pmf.sorted_desc();
        let opt = optimizer::optimize_scheme(&sorted);
        println!(
            "{:>8} {:>9.3} {:>9.2} {:>9.2}",
            spec.name(),
            pmf.entropy(),
            pmf.ideal_compressibility() * 100.0,
            opt.compressibility_sorted(&sorted) * 100.0
        );
    }

    println!("\n=== ablation 6: block compressors & streaming adaptation ===");
    // Drifting stream: first half FFN1-like, second half FFN2-like.
    let gen1 = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
    let gen2 = TensorGen::new(TensorKind::Ffn2Act, Variant::ExmY);
    let mut rng = Rng::new(23);
    let stream = [
        gen1.symbols(&mut rng, n),
        gen2.symbols(&mut rng, n),
    ]
    .concat();
    let hist = Histogram::from_symbols(&stream);
    let static_qlc = {
        let pmf = hist.pmf();
        let scheme = optimizer::optimize_scheme(&pmf.sorted_desc());
        qlc::codecs::qlc::QlcCodec::from_pmf(scheme, &pmf)
    };
    let static_len = static_qlc.encode_to_vec(&stream).len();
    let adaptive_len = adaptive::encode(
        &AdaptiveConfig { reoptimize_scheme: true, ..Default::default() },
        &stream,
    )
    .len();
    let comp = |len: usize| (1.0 - len as f64 / stream.len() as f64) * 100.0;
    println!("  qlc static (oracle full-stream LUT)  {:>6.2}%", comp(static_len));
    println!("  qlc adaptive (streaming, no oracle)  {:>6.2}%", comp(adaptive_len));
    #[cfg(feature = "zstd")]
    for level in [1, 3, 9] {
        println!(
            "  zstd level {level}                         {:>6.2}%  (block compressor, context-aware)",
            zstd_baseline::compressibility(&stream, level) * 100.0
        );
    }
    #[cfg(not(feature = "zstd"))]
    println!("  zstd baseline skipped (build with --features zstd)");
    let huff = HuffmanCodec::from_histogram(&hist);
    println!(
        "  huffman static                       {:>6.2}%",
        comp(huff.encode_to_vec(&stream).len())
    );
}
