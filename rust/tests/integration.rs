//! Cross-module integration tests: generators → quantizer → codecs →
//! frames → pipeline → collectives, plus trace persistence.

use qlc::codecs::frame::{self, FrameOptions};
use qlc::codecs::CodecRegistry;
use qlc::codecs::qlc::{optimizer, AreaScheme, QlcCodec};
use qlc::codecs::Codec;
use qlc::collective::{self, engine, Fabric, Transport};
use qlc::coordinator::{Pipeline, PipelineConfig};
use qlc::data::trace::Trace;
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::{BlockQuantizer, Variant, BLOCK};
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

fn gen_symbols(kind: TensorKind, n: usize, seed: u64) -> Vec<u8> {
    let gen = TensorGen::new(kind, Variant::ExmY);
    let mut rng = Rng::new(seed);
    gen.symbols(&mut rng, n)
}

#[test]
fn full_tensor_compression_roundtrip() {
    // f32 tensor → quantize → compress (every codec) → decompress →
    // dequantize; symbols bit-exact, values within quantization error.
    let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
    let mut rng = Rng::new(1);
    let data = gen.generate(&mut rng, 512 * BLOCK);
    let quant = BlockQuantizer::new(Variant::ExmY);
    let q = quant.quantize(&data);
    let hist = Histogram::from_symbols(&q.symbols);
    let registry = CodecRegistry::global();
    for name in registry.known_names() {
        let handle = registry.resolve(name, &hist).unwrap();
        // Chunked QLF2 (default), small-chunk QLF2, and legacy QLF1.
        let framed = frame::compress(&handle, &q.symbols).unwrap();
        assert_eq!(frame::decompress(&framed).unwrap(), q.symbols, "{name}");
        let small = frame::compress_with(
            &handle,
            &q.symbols,
            &FrameOptions { chunk_symbols: 1000, ..Default::default() },
        )
        .unwrap();
        assert_eq!(frame::decompress(&small).unwrap(), q.symbols, "{name}");
        let v1 = frame::compress_qlf1(&handle, &q.symbols);
        assert_eq!(frame::decompress(&v1).unwrap(), q.symbols, "{name}");
    }
    let deq = quant.dequantize(&q);
    for (x, y) in data.iter().zip(&deq) {
        assert!((x - y).abs() <= x.abs() * 0.07 + 1e-3);
    }
}

#[test]
fn per_tensor_type_luts_like_paper_section7() {
    // Paper §7: one LUT per tensor type, fitted apriori, then applied
    // to fresh data of the same type.  Cross-type application must
    // still roundtrip (lossless), just compress worse.
    let kinds = [TensorKind::Ffn1Act, TensorKind::Ffn2Act];
    let codecs: Vec<QlcCodec> = kinds
        .iter()
        .map(|&k| {
            let pmf =
                Histogram::from_symbols(&gen_symbols(k, 256 * BLOCK, 7)).pmf();
            let scheme = optimizer::optimize_scheme(&pmf.sorted_desc());
            QlcCodec::from_pmf(scheme, &pmf)
        })
        .collect();
    for (i, &kind) in kinds.iter().enumerate() {
        let fresh = gen_symbols(kind, 64 * BLOCK, 99);
        let matched = codecs[i].encode_to_vec(&fresh);
        let mismatched = codecs[1 - i].encode_to_vec(&fresh);
        assert_eq!(
            codecs[i].decode_from_slice(&matched, fresh.len()).unwrap(),
            fresh
        );
        assert_eq!(
            codecs[1 - i]
                .decode_from_slice(&mismatched, fresh.len())
                .unwrap(),
            fresh
        );
        assert!(
            matched.len() <= mismatched.len(),
            "matched LUT must compress at least as well ({} vs {})",
            matched.len(),
            mismatched.len()
        );
    }
}

#[test]
fn pipeline_feeds_collective() {
    // Coordinator-compressed frames decompress into the data that a
    // collective then reduces — the full L3 path.
    let w = 4;
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(3);
    let per_worker: Vec<Vec<f32>> =
        (0..w).map(|_| gen.generate(&mut rng, w * BLOCK * 4)).collect();
    let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 128 * BLOCK));

    // Stage 1: pipeline roundtrip of the quantized gradients.
    let quant = BlockQuantizer::new(Variant::ExmY);
    let pipe = Pipeline::new(
        PipelineConfig { workers: 2, chunk_size: 1000, queue_depth: 2 },
        "qlc",
        &cal,
    )
    .unwrap();
    for data in &per_worker {
        let q = quant.quantize(data);
        assert_eq!(pipe.roundtrip(&q.symbols).unwrap(), q.symbols);
    }

    // Stage 2: compressed all-reduce equals raw all-reduce.
    let fabric = Fabric::pod(w);
    let transport = Transport::Compressed {
        codec: "qlc".into(),
        calibration: Box::new(cal),
    };
    let (compressed, _) =
        collective::ring_allreduce(&fabric, &per_worker, &transport).unwrap();
    let (raw, _) =
        collective::ring_allreduce(&fabric, &per_worker, &Transport::Raw)
            .unwrap();
    assert_eq!(compressed, raw);
}

#[test]
fn threaded_engine_consistent_with_sim_across_codecs() {
    let w = 3;
    let gen = TensorGen::new(TensorKind::Ffn2Act, Variant::ExmY);
    let mut rng = Rng::new(5);
    let data: Vec<Vec<f32>> =
        (0..w).map(|_| gen.generate(&mut rng, w * BLOCK * 8)).collect();
    let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 128 * BLOCK));
    for codec in ["huffman", "qlc", "elias-delta"] {
        let transport = Transport::Compressed {
            codec: codec.into(),
            calibration: Box::new(cal.clone()),
        };
        let fabric = Fabric::pod(w);
        let (sim, _) =
            collective::ring_allreduce(&fabric, &data, &transport).unwrap();
        let (thr, _) =
            engine::threaded_allreduce(w, data.clone(), &transport).unwrap();
        assert_eq!(sim, thr, "{codec}");
    }
}

#[test]
fn chunk_pipelined_backends_agree_with_whole_payload_path() {
    // The acceptance bar for the transport refactor: simulated and
    // threaded chunk-pipelined all-reduce agree bit-for-bit with the
    // whole-payload path, and the simulator's pipelined wall time
    // never exceeds the non-pipelined one.
    let w = 4;
    let gen = TensorGen::new(TensorKind::WeightGrad, Variant::ExmY);
    let mut rng = Rng::new(41);
    let data: Vec<Vec<f32>> =
        (0..w).map(|_| gen.generate(&mut rng, w * BLOCK * 32)).collect();
    let cal = Histogram::from_symbols(&gen.symbols(&mut rng, 128 * BLOCK));
    let transport = Transport::Compressed {
        codec: "qlc".into(),
        calibration: Box::new(cal),
    };
    let fabric = Fabric::ethernet(w);
    let (whole, _) = collective::ring_allreduce_with(
        &fabric,
        &data,
        &transport,
        usize::MAX,
    )
    .unwrap();
    let (sim_chunked, rep) = collective::ring_allreduce_with(
        &fabric,
        &data,
        &transport,
        2 * BLOCK,
    )
    .unwrap();
    let (thr_chunked, _) = engine::threaded_allreduce_with(
        w,
        data.clone(),
        &transport,
        2 * BLOCK,
        2,
    )
    .unwrap();
    assert_eq!(sim_chunked, whole);
    assert_eq!(thr_chunked, whole);
    assert!(rep.pipelined_time_s > 0.0);
    assert!(rep.pipelined_time_s <= rep.total_time_s());
}

#[test]
fn sharded_coordinator_roundtrip_with_shuffled_arrival() {
    // Coordinator places shard descriptors on workers; the resulting
    // manifest + shard set reassembles bit-exactly even when shards
    // arrive out of order (as they would off N placement nodes).
    let symbols = gen_symbols(TensorKind::Ffn1Act, 700 * BLOCK, 29);
    let hist = Histogram::from_symbols(&symbols);
    let pipe = Pipeline::new(
        PipelineConfig { workers: 3, chunk_size: 4096, queue_depth: 4 },
        "qlc",
        &hist,
    )
    .unwrap();
    let (manifest, mut shards) = pipe.compress_sharded(&symbols, 6).unwrap();
    assert_eq!(manifest.n_shards(), shards.len());
    // Manifest survives serialization (as it would ship to consumers).
    let manifest =
        frame::ShardManifest::parse(&manifest.to_bytes()).unwrap();
    shards.reverse();
    shards.rotate_left(1);
    let back = frame::decompress_sharded(
        &manifest,
        &shards,
        &FrameOptions::default(),
    )
    .unwrap();
    assert_eq!(back, symbols);
}

#[test]
fn trace_roundtrip_preserves_compressibility() {
    let dir = std::env::temp_dir()
        .join(format!("qlc-int-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let symbols = gen_symbols(TensorKind::Ffn1Act, 512 * BLOCK, 11);
    Trace::new("t", symbols.clone())
        .with_meta("kind", "ffn1_act")
        .save(&dir)
        .unwrap();
    let back = Trace::load(&dir, "t").unwrap();
    assert_eq!(back.symbols, symbols);
    let hist = Histogram::from_symbols(&back.symbols);
    let handle = CodecRegistry::global().resolve("qlc", &hist).unwrap();
    let framed = frame::compress(&handle, &back.symbols).unwrap();
    assert!(framed.len() < symbols.len());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn scheme_serialization_ships_between_processes() {
    // Paper §7 / ref [12]: LUTs computed apriori and shipped.  Emulate
    // with a JSON round-trip through a file.
    let pmf =
        Histogram::from_symbols(&gen_symbols(TensorKind::Ffn2Act, 512 * BLOCK, 13))
            .pmf();
    let codec = QlcCodec::from_pmf(AreaScheme::table2(), &pmf);
    let json = qlc::codecs::qlc::serde::to_json(&codec);
    let path = std::env::temp_dir()
        .join(format!("qlc-scheme-{}.json", std::process::id()));
    std::fs::write(&path, json.to_string_pretty()).unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let parsed = qlc::util::json::Json::parse(&text).unwrap();
    let shipped =
        qlc::codecs::qlc::serde::from_json(&parsed, "shipped").unwrap();
    let data = gen_symbols(TensorKind::Ffn2Act, 32 * BLOCK, 17);
    let enc = codec.encode_to_vec(&data);
    assert_eq!(shipped.decode_from_slice(&enc, data.len()).unwrap(), data);
    std::fs::remove_file(&path).unwrap();
}

#[test]
fn compressibility_ranking_headline() {
    // The paper's core comparison on FFN1-like data: Huffman ≥ QLC-opt
    // ≥ QLC-T1 > ranked-EG; everything beats raw.
    let symbols = gen_symbols(TensorKind::Ffn1Act, 2048 * BLOCK, 19);
    let hist = Histogram::from_symbols(&symbols);
    let len = |name: &str| {
        let handle = CodecRegistry::global().resolve(name, &hist).unwrap();
        handle.codec().encode_to_vec(&symbols).len()
    };
    let raw = symbols.len();
    let huff = len("huffman");
    let qlc_opt = len("qlc");
    let qlc_t1 = len("qlc-t1");
    assert!(huff <= qlc_opt, "{huff} vs {qlc_opt}");
    assert!(qlc_opt <= qlc_t1, "{qlc_opt} vs {qlc_t1}");
    assert!(qlc_t1 < raw);
}

#[test]
fn corrupted_frames_never_panic() {
    // Failure injection: random bit flips, truncations and garbage must
    // produce Err (or, for payload-internal flips the codec cannot
    // detect, a wrong-but-sized output) — never a panic or OOM.
    let symbols = gen_symbols(TensorKind::Ffn1Act, 128 * BLOCK, 23);
    let hist = Histogram::from_symbols(&symbols);
    let mut rng = Rng::new(99);
    for name in ["huffman", "qlc", "elias-gamma", "eg2", "raw"] {
        let handle = CodecRegistry::global().resolve(name, &hist).unwrap();
        let frame_bytes = frame::compress(&handle, &symbols).unwrap();
        for _ in 0..200 {
            let mut corrupt = frame_bytes.clone();
            match rng.below(3) {
                0 => {
                    // single bit flip
                    let i = rng.below(corrupt.len() as u64) as usize;
                    corrupt[i] ^= 1 << rng.below(8);
                }
                1 => {
                    // truncate
                    let keep = rng.below(corrupt.len() as u64) as usize;
                    corrupt.truncate(keep);
                }
                _ => {
                    // splice garbage
                    let i = rng.below(corrupt.len() as u64) as usize;
                    let mut junk = vec![0u8; 16.min(corrupt.len() - i)];
                    rng.fill_bytes(&mut junk);
                    corrupt[i..i + junk.len()].copy_from_slice(&junk);
                }
            }
            match frame::decompress(&corrupt) {
                Ok(out) => assert!(out.len() <= symbols.len() + 1),
                Err(_) => {}
            }
        }
    }
}

#[test]
fn ocp_variant_end_to_end() {
    // The OCP e4m3 (2 NaN encodings) path: quantize, compress,
    // decompress, dequantize — NaN codes never appear on the wire.
    let mut rng = Rng::new(31);
    let mut data = vec![0f32; 256 * BLOCK];
    rng.fill_normal_f32(&mut data, 0.0, 3.0);
    let quant = BlockQuantizer::new(Variant::Ocp);
    let q = quant.quantize(&data);
    assert!(q.symbols.iter().all(|&s| (s & 0x7F) != 0x7F));
    let hist = Histogram::from_symbols(&q.symbols);
    let handle = CodecRegistry::global().resolve("qlc", &hist).unwrap();
    let framed = frame::compress(&handle, &q.symbols).unwrap();
    assert_eq!(frame::decompress(&framed).unwrap(), q.symbols);
    let deq = quant.dequantize(&q);
    assert!(deq.iter().all(|v| v.is_finite()));
}

#[test]
fn huffman_qlc_agree_on_degenerate_streams() {
    // Single-symbol and two-symbol streams: extreme PMFs that stress
    // smoothing, Kraft handling and area assignment.
    for stream in [vec![42u8; 4096], {
        let mut v = vec![0u8; 4096];
        v[4095] = 255;
        v
    }] {
        let hist = Histogram::from_symbols(&stream);
        for name in ["huffman", "qlc", "qlc-t1"] {
            let handle =
                CodecRegistry::global().resolve(name, &hist).unwrap();
            let framed = frame::compress(&handle, &stream).unwrap();
            assert_eq!(frame::decompress(&framed).unwrap(), stream, "{name}");
        }
    }
}
