//! In-process `qlc serve` acceptance tests: a real [`Server`] event
//! loop on a thread, real loopback sockets, real [`ServeClient`]
//! request pumps.  The bar everywhere is bit-exactness: whatever goes
//! up a compress stream must come back identical through a decompress
//! stream.

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use qlc::codecs::{CodecHandle, CodecRegistry};
use qlc::data::{TensorGen, TensorKind};
use qlc::formats::Variant;
use qlc::serve::{
    chunks_from_raw, concat_payloads, ClientConfig, LoadgenConfig,
    ServeClient, ServeSummary, Server, ServerConfig,
};
use qlc::stats::Histogram;
use qlc::transport::net::serve_wire::{self, Op};
use qlc::transport::reactor::Backend;
use qlc::util::rng::Rng;

struct TestServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<Result<ServeSummary, String>>>,
}

impl TestServer {
    fn start(cfg: ServerConfig) -> TestServer {
        let mut server = Server::bind("127.0.0.1:0", cfg).unwrap();
        let addr = server.local_addr().to_string();
        let stop = server.shutdown_handle();
        let handle = std::thread::spawn(move || server.run());
        TestServer { addr, stop, handle: Some(handle) }
    }

    fn finish(mut self) -> ServeSummary {
        self.stop.store(true, Ordering::Relaxed);
        self.handle.take().unwrap().join().unwrap().unwrap()
    }
}

impl Drop for TestServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn payload(seed: u64, n: usize) -> Vec<u8> {
    let gen = TensorGen::new(TensorKind::Ffn1Act, Variant::ExmY);
    let mut rng = Rng::new(seed);
    gen.symbols(&mut rng, n)
}

fn handle_for(data: &[u8], codec: &str) -> CodecHandle {
    let hist = Histogram::from_symbols(data);
    CodecRegistry::global().resolve(codec, &hist).unwrap()
}

fn client_cfg() -> ClientConfig {
    ClientConfig {
        backend: Backend::Auto,
        timeout: Duration::from_secs(30),
        chunk: 16 * 1024,
    }
}

/// One stream, several requests per connection: the session pair must
/// survive (and stay correct) across request boundaries.
#[test]
fn roundtrip_reuses_sessions_across_requests() {
    let server = TestServer::start(ServerConfig::default());
    let data = payload(7, 256 * 1024);
    let handle = handle_for(&data, "qlc");
    let cfg = client_cfg();
    let mut comp =
        ServeClient::connect(&server.addr, &handle, Op::Compress, &cfg)
            .unwrap();
    let mut deco =
        ServeClient::connect(&server.addr, &handle, Op::Decompress, &cfg)
            .unwrap();
    let chunks = chunks_from_raw(&data, cfg.chunk);
    assert!(chunks.len() > 1, "want a multi-chunk request");
    let mut wire_total = 0usize;
    for _ in 0..3 {
        let compressed = comp.request(&chunks).unwrap();
        assert_eq!(compressed.len(), chunks.len());
        wire_total +=
            compressed.iter().map(|c| c.payload.len()).sum::<usize>();
        let back = deco.request(&compressed).unwrap();
        assert_eq!(concat_payloads(&back), data, "round trip diverged");
    }
    assert!(wire_total > 0);
    drop(comp);
    drop(deco);
    let summary = server.finish();
    assert_eq!(summary.requests, 6);
    assert_eq!(summary.conns, 2);
}

/// Zero-length payloads still round-trip (single empty last chunk).
#[test]
fn roundtrip_empty_payload() {
    let server = TestServer::start(ServerConfig::default());
    let data = payload(11, 64);
    let handle = handle_for(&data, "qlc");
    let cfg = client_cfg();
    let mut comp =
        ServeClient::connect(&server.addr, &handle, Op::Compress, &cfg)
            .unwrap();
    let mut deco =
        ServeClient::connect(&server.addr, &handle, Op::Decompress, &cfg)
            .unwrap();
    let chunks = chunks_from_raw(&[], cfg.chunk);
    let compressed = comp.request(&chunks).unwrap();
    let back = deco.request(&compressed).unwrap();
    assert_eq!(concat_payloads(&back), Vec::<u8>::new());
}

/// M=4 concurrent verified streams through one server event loop.
#[test]
fn concurrent_streams_all_verify() {
    let server = TestServer::start(ServerConfig::default());
    let report = qlc::serve::run_loadgen(&LoadgenConfig {
        addr: server.addr.clone(),
        streams: 4,
        requests: 3,
        size: 128 * 1024,
        chunk: 16 * 1024,
        codec: "qlc".to_string(),
        backend: Backend::Auto,
        verify: true,
        seed: 99,
        timeout: Duration::from_secs(30),
    })
    .unwrap();
    assert_eq!(report.requests, 12, "4 streams x 3 round trips");
    assert_eq!(report.verified, 12);
    assert!(report.aggregate_mbps > 0.0);
    assert!(
        report.p50_compress_ns > 0 && report.p99_compress_ns > 0,
        "compress latency quantiles missing: {report:?}"
    );
    assert!(
        report.p50_decompress_ns > 0 && report.p99_decompress_ns > 0,
        "decompress latency quantiles missing: {report:?}"
    );
    assert!(report.p99_compress_ns >= report.p50_compress_ns);
    let summary = server.finish();
    // Each round trip is one compress plus one decompress request.
    assert_eq!(summary.requests, 24);
    assert_eq!(summary.conns, 8);
}

/// A connection whose output queue is capped to a few KB must still
/// complete multi-chunk requests (flow control, not deadlock), and a
/// parallel stream on the same server must be unaffected.
#[test]
fn tiny_output_queue_still_drains() {
    let server = TestServer::start(ServerConfig {
        out_hiwater: 2 * 1024,
        ..ServerConfig::default()
    });
    let report = qlc::serve::run_loadgen(&LoadgenConfig {
        addr: server.addr.clone(),
        streams: 2,
        requests: 2,
        size: 192 * 1024,
        chunk: 8 * 1024,
        codec: "qlc".to_string(),
        backend: Backend::Auto,
        verify: true,
        seed: 5,
        timeout: Duration::from_secs(30),
    })
    .unwrap();
    assert_eq!(report.verified, 4);
}

/// Satellite: a live server must answer garbage, truncated magic and
/// unresolvable codecs with an explanatory QSA1 error ack and then
/// close — never hang, never take the accept loop down with it.
#[test]
fn malformed_handshakes_get_error_acks() {
    let server = TestServer::start(ServerConfig::default());
    let bad_handshakes: Vec<Vec<u8>> = vec![
        b"GARBAGE-NOT-A-HANDSHAKE----".to_vec(),
        // Right magic, unsupported version.
        {
            let mut b = b"QSV1".to_vec();
            b.push(99);
            b.extend_from_slice(&[1, 0, 0, 0, 0, 0]);
            b
        },
        // Valid layout, but an op byte the protocol does not define.
        {
            let mut b = b"QSV1".to_vec();
            b.push(1);
            b.push(7);
            b.push(0);
            b.extend_from_slice(&0u32.to_le_bytes());
            b
        },
        // Well-formed handshake naming an unregistered codec tag.
        {
            let mut b = Vec::new();
            serve_wire::encode_handshake(
                &serve_wire::Handshake {
                    op: Op::Compress,
                    codec_tag: 0xEE,
                    header: vec![1, 2, 3],
                },
                &mut b,
            )
            .unwrap();
            b
        },
    ];
    for (i, hs) in bad_handshakes.iter().enumerate() {
        let mut stream = TcpStream::connect(&server.addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(10)))
            .unwrap();
        stream.write_all(hs).unwrap();
        let mut buf = Vec::new();
        let ack = loop {
            if let Some((ack, _)) = serve_wire::decode_ack(&buf).unwrap() {
                break ack;
            }
            let mut chunk = [0u8; 256];
            let n = stream.read(&mut chunk).unwrap();
            assert!(n > 0, "handshake {i}: server closed without an ack");
            buf.extend_from_slice(&chunk[..n]);
        };
        assert!(!ack.ok, "handshake {i} was accepted: {hs:?}");
        assert!(!ack.msg.is_empty(), "handshake {i}: empty reject reason");
        // After the reject ack the server closes the connection.
        let mut rest = [0u8; 16];
        let n = stream.read(&mut rest).unwrap_or(0);
        assert_eq!(n, 0, "handshake {i}: server kept the stream open");
    }
    // The server survived all of that: a well-formed client still works.
    let data = payload(3, 32 * 1024);
    let handle = handle_for(&data, "qlc");
    let cfg = client_cfg();
    let mut comp =
        ServeClient::connect(&server.addr, &handle, Op::Compress, &cfg)
            .unwrap();
    let compressed = comp.request(&chunks_from_raw(&data, cfg.chunk)).unwrap();
    assert!(!compressed.is_empty());
}

/// A rejected handshake surfaces the server's reason through
/// [`ServeClient::connect`].
#[test]
fn client_reports_handshake_rejection() {
    let server = TestServer::start(ServerConfig::default());
    let data = payload(13, 4096);
    let handle = handle_for(&data, "qlc");
    let cfg = client_cfg();
    // Corrupt the codec identity by resolving a handle, then lying
    // about the tag via a raw handshake: simplest is a direct call
    // with a handle whose header the server cannot parse.  Use the
    // raw-socket path above for that; here check the error text path
    // with an empty header for a codec that requires one.
    let mut raw = TcpStream::connect(&server.addr).unwrap();
    let mut b = Vec::new();
    serve_wire::encode_handshake(
        &serve_wire::Handshake {
            op: Op::Decompress,
            codec_tag: handle.wire_tag(),
            header: vec![0xFF; 3],
        },
        &mut b,
    )
    .unwrap();
    raw.write_all(&b).unwrap();
    raw.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut buf = Vec::new();
    let ack = loop {
        if let Some((ack, _)) = serve_wire::decode_ack(&buf).unwrap() {
            break ack;
        }
        let mut chunk = [0u8; 256];
        let n = raw.read(&mut chunk).unwrap();
        if n == 0 {
            panic!("no ack before close");
        }
        buf.extend_from_slice(&chunk[..n]);
    };
    assert!(!ack.ok);
    drop(raw);
    // And the proper client path still connects fine afterwards.
    let c = ServeClient::connect(&server.addr, &handle, Op::Compress, &cfg);
    assert!(c.is_ok(), "{:?}", c.err());
}

/// `max_requests` drains in-flight connections, then the loop exits
/// on its own (no shutdown flag involved).
#[test]
fn max_requests_drains_and_exits() {
    let mut server = Server::bind(
        "127.0.0.1:0",
        ServerConfig { max_requests: 2, ..ServerConfig::default() },
    )
    .unwrap();
    let addr = server.local_addr().to_string();
    let handle = std::thread::spawn(move || server.run());
    let data = payload(21, 64 * 1024);
    let codec = handle_for(&data, "qlc");
    let cfg = client_cfg();
    let mut comp =
        ServeClient::connect(&addr, &codec, Op::Compress, &cfg).unwrap();
    let chunks = chunks_from_raw(&data, cfg.chunk);
    comp.request(&chunks).unwrap();
    comp.request(&chunks).unwrap();
    drop(comp);
    let summary = handle.join().unwrap().unwrap();
    assert_eq!(summary.requests, 2);
}
