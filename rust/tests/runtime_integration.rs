//! PJRT runtime integration: the AOT JAX/Pallas artifacts loaded and
//! executed from Rust, checked for bit-parity with the Rust quantizer.
//!
//! These tests need `artifacts/` (built by `make artifacts`); they are
//! skipped — loudly — if it is missing, so plain `cargo test` works in
//! a fresh checkout.  The whole file is gated on the `pjrt` feature
//! (the runtime needs the external `xla` + `anyhow` crates; see
//! Cargo.toml).

#![cfg(feature = "pjrt")]

use std::path::Path;

use qlc::formats::{BlockQuantizer, Variant};
use qlc::runtime::inputs::{make_step_inputs, InputStats};
use qlc::runtime::Runtime;
use qlc::stats::Histogram;
use qlc::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts`");
        return None;
    }
    Some(Runtime::load(dir).expect("artifacts present but unloadable"))
}

#[test]
fn pallas_kernel_bit_parity_with_rust_quantizer() {
    let Some(rt) = runtime() else { return };
    let n = rt.quant_blocks() * 32;
    let quant = BlockQuantizer::new(Variant::ExmY);
    for seed in [1u64, 2, 3] {
        let mut rng = Rng::new(seed);
        let mut data = vec![0f32; n];
        // Mix of scales to stress the boundary table.
        for (i, v) in data.iter_mut().enumerate() {
            let scale = 2.0f64.powi((i % 61) as i32 - 30);
            *v = (rng.normal() * scale) as f32;
        }
        let (syms, scales) = rt.quantize_blocks(&data).unwrap();
        let q = quant.quantize(&data);
        assert_eq!(syms, q.symbols, "seed {seed}: symbol mismatch");
        assert_eq!(scales, q.scales, "seed {seed}: scale mismatch");
    }
}

#[test]
fn harvest_step_produces_paper_tensor_families() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(42);
    let inputs =
        make_step_inputs(rt.input_shapes(), InputStats::default(), &mut rng);
    let tensors = rt.harvest_step(&inputs).unwrap();
    assert_eq!(tensors.len(), 8);
    let names: Vec<&str> = tensors.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(
        names,
        vec![
            "ffn1_act",
            "ffn2_act",
            "ffn1_weight",
            "ffn2_weight",
            "ffn1_wgrad",
            "ffn2_wgrad",
            "ffn1_agrad",
            "ffn2_agrad"
        ]
    );
    for t in &tensors {
        assert_eq!(t.symbols.len(), t.scales.len() * 32, "{}", t.name);
        let pmf = Histogram::from_symbols(&t.symbols).pmf();
        let h = pmf.entropy();
        assert!((4.0..8.0).contains(&h), "{}: entropy {h}", t.name);
        match t.name.as_str() {
            // Paper Fig. 4: the post-GeGLU tensors carry a zero spike.
            "ffn2_act" | "ffn1_agrad" => {
                assert!(pmf.p[0] > 0.03, "{}: p0 {}", t.name, pmf.p[0])
            }
            // Paper Fig. 1: pre-nonlinearity tensors do not.
            "ffn1_act" | "ffn1_weight" => {
                assert!(pmf.p[0] < 0.01, "{}: p0 {}", t.name, pmf.p[0])
            }
            _ => {}
        }
    }
}

#[test]
fn harvest_deterministic_for_seed() {
    let Some(rt) = runtime() else { return };
    let run = |seed: u64| {
        let mut rng = Rng::new(seed);
        let inputs = make_step_inputs(
            rt.input_shapes(),
            InputStats::default(),
            &mut rng,
        );
        rt.harvest_step(&inputs).unwrap()
    };
    let a = run(7);
    let b = run(7);
    let c = run(8);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.symbols, y.symbols, "{}", x.name);
    }
    assert!(
        a.iter().zip(&c).any(|(x, y)| x.symbols != y.symbols),
        "different seeds must differ"
    );
}

#[test]
fn harvest_rejects_bad_inputs() {
    let Some(rt) = runtime() else { return };
    // Wrong arity.
    assert!(rt.harvest_step(&[vec![0f32; 16]]).is_err());
    // Wrong length for x.
    let mut rng = Rng::new(1);
    let mut inputs =
        make_step_inputs(rt.input_shapes(), InputStats::default(), &mut rng);
    inputs[0].pop();
    assert!(rt.harvest_step(&inputs).is_err());
    // Wrong length for quantize.
    assert!(rt.quantize_blocks(&[0f32; 31]).is_err());
}
