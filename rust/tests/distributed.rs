//! Loopback multi-process distributed collective tests: spawn real
//! `qlc worker` processes that rendezvous over 127.0.0.1 TCP, run the
//! ring collective, and check the result against the in-process
//! threaded engine bit-for-bit.  This is the acceptance path for the
//! TCP transport: same inputs, same codec tables, different transport
//! — identical bits.

use std::path::PathBuf;
use std::process::{Command, Stdio};

use qlc::collective::dist;
use qlc::collective::engine::threaded_allreduce;
use qlc::collective::Transport;
use qlc::formats::BLOCK;

fn qlc_bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_qlc"))
}

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("qlc-dist-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn four_process_allreduce_matches_threaded_engine_bit_for_bit() {
    let world = 4usize;
    let elems = world * BLOCK * 8; // per-rank f32s, world×BLOCK aligned
    let seed = 7u64;
    let addr = dist::free_loopback_addr().unwrap();
    let dir = tmp("allreduce");

    let mut children = Vec::new();
    for rank in 0..world {
        let out = dir.join(format!("rank{rank}.f32"));
        let mut argv: Vec<String> = vec![
            "worker".to_string(),
            "--world".to_string(),
            "4".to_string(),
            "--rank".to_string(),
            rank.to_string(),
            "--codec".to_string(),
            "qlc".to_string(),
            "--size".to_string(),
            elems.to_string(),
            "--seed".to_string(),
            "7".to_string(),
            "--timeout-s".to_string(),
            "60".to_string(),
            "--json".to_string(),
            "--out".to_string(),
            out.to_str().unwrap().to_string(),
        ];
        let role = if rank == 0 { "--listen" } else { "--connect" };
        argv.push(role.to_string());
        argv.push(addr.clone());
        let mut cmd = qlc_bin();
        cmd.args(argv);
        cmd.stdout(Stdio::piped()).stderr(Stdio::piped());
        children.push(cmd.spawn().unwrap());
    }
    let mut checksums = Vec::new();
    for (rank, child) in children.into_iter().enumerate() {
        let out = child.wait_with_output().unwrap();
        assert!(
            out.status.success(),
            "rank {rank} failed: {}",
            String::from_utf8_lossy(&out.stderr)
        );
        let text = String::from_utf8_lossy(&out.stdout);
        let json = qlc::util::json::Json::parse(text.trim()).unwrap();
        checksums.push(
            json.get("checksum").and_then(|j| j.as_str()).unwrap().to_string(),
        );
        // Measured wall time: pipelined never exceeds the serial
        // estimate (wire share + codec back-to-back).
        let total = json.get("total_time_s").unwrap().as_f64().unwrap();
        let pipelined =
            json.get("pipelined_time_s").unwrap().as_f64().unwrap();
        assert!(
            pipelined <= total * (1.0 + 1e-9),
            "rank {rank}: {pipelined} > {total}"
        );
    }
    for c in &checksums[1..] {
        assert_eq!(c, &checksums[0], "ranks disagree");
    }

    // The in-process engine over identically generated tensors must
    // produce the same bits the processes wrote.
    let data: Vec<Vec<f32>> =
        (0..world).map(|r| dist::rank_tensor(seed, r, elems)).collect();
    let transport = Transport::Compressed {
        codec: "qlc".into(),
        calibration: Box::new(dist::calibration(seed)),
    };
    let (expect, _) = threaded_allreduce(world, data, &transport).unwrap();
    for rank in 0..world {
        let bytes =
            std::fs::read(dir.join(format!("rank{rank}.f32"))).unwrap();
        let want: Vec<u8> =
            expect[rank].iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(
            bytes, want,
            "rank {rank} diverged from the threaded engine"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn launch_world_4_acceptance() {
    // The headline acceptance criterion: `qlc launch --world 4`
    // completes a ring allreduce over 127.0.0.1 TCP sockets with
    // bit-identical results and pipelined ≤ serial from measured wall
    // time.
    let out = qlc_bin()
        .args([
            "launch", "--world", "4", "--op", "allreduce", "--codec",
            "qlc", "--size", "16384", "--seed", "3", "--timeout-s", "60",
            "--json",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "launch failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let json = qlc::util::json::Json::parse(
        String::from_utf8_lossy(&out.stdout).trim(),
    )
    .unwrap();
    assert_eq!(json.get("agree").and_then(|j| j.as_bool()), Some(true));
    let rank0 = json.get("rank0").unwrap();
    let total = rank0.get("total_time_s").unwrap().as_f64().unwrap();
    let pipelined =
        rank0.get("pipelined_time_s").unwrap().as_f64().unwrap();
    assert!(pipelined > 0.0);
    assert!(pipelined <= total * (1.0 + 1e-9), "{pipelined} > {total}");
    let ratio =
        rank0.get("compression_ratio").unwrap().as_f64().unwrap();
    assert!(ratio > 1.0, "qlc transport must compress ({ratio})");
}

#[test]
fn launch_allgather_shards_smoke() {
    // Shard-granular gather across processes: 3 workers each encode
    // one QLS1 shard, circulate bodies, reassemble identically.
    let out = qlc_bin()
        .args([
            "launch", "--world", "3", "--op", "allgather", "--codec",
            "qlc", "--size", "12288", "--timeout-s", "60", "--json",
        ])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "launch failed: {}",
        String::from_utf8_lossy(&out.stderr)
    );
    let json = qlc::util::json::Json::parse(
        String::from_utf8_lossy(&out.stdout).trim(),
    )
    .unwrap();
    assert_eq!(json.get("agree").and_then(|j| j.as_bool()), Some(true));
    let rank0 = json.get("rank0").unwrap();
    assert_eq!(
        rank0.get("op").and_then(|j| j.as_str()),
        Some("allgather_shards")
    );
}

#[test]
fn worker_flag_validation_fails_fast() {
    // No sockets involved: these must all fail with clean CLI errors.
    for bad in [
        vec!["worker"],                                    // no --world
        vec!["worker", "--world", "2"],                    // rank 0, no listen
        vec!["worker", "--world", "2", "--rank", "1"],     // no connect
        vec!["worker", "--world", "2", "--rank", "5", "--connect", "x"],
        vec!["worker", "--world", "0"],
        vec![
            "worker", "--world", "2", "--listen", "a", "--connect", "b",
        ],
        vec![
            "worker", "--world", "2", "--rank", "1", "--connect",
            "127.0.0.1:1", "--op", "broadcast",
        ],
        vec![
            "worker", "--world", "2", "--rank", "1", "--connect",
            "127.0.0.1:1", "--size", "3",
        ], // below one alignment unit
    ] {
        let out = qlc_bin().args(&bad).output().unwrap();
        assert!(!out.status.success(), "expected failure for {bad:?}");
    }
}

#[test]
fn world_one_worker_needs_no_sockets() {
    let out = qlc_bin()
        .args(["worker", "--world", "1", "--size", "1024", "--json"])
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "{}",
        String::from_utf8_lossy(&out.stderr)
    );
    let json = qlc::util::json::Json::parse(
        String::from_utf8_lossy(&out.stdout).trim(),
    )
    .unwrap();
    assert_eq!(json.get("steps").and_then(|j| j.as_usize()), Some(0));
}
