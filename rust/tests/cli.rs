//! CLI smoke tests: drive the `qlc` binary end-to-end through its
//! subcommands (compress/decompress file roundtrip, tables, analyze,
//! optimize, collective, datagen).

use std::path::PathBuf;
use std::process::Command;

fn qlc() -> Command {
    Command::new(env!("CARGO_BIN_EXE_qlc"))
}

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("qlc-cli-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_lists_subcommands() {
    let out = qlc().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["tables", "compress", "collective", "hw", "serve"] {
        assert!(text.contains(cmd), "{cmd} missing from help");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let out = qlc().arg("wat").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn compress_decompress_file_roundtrip() {
    let dir = tmp("roundtrip");
    let input = dir.join("in.bin");
    // Skewed but not degenerate content.
    let data: Vec<u8> = (0..100_000u64)
        .map(|i| (i.wrapping_mul(i) % 97 % 64) as u8)
        .collect();
    std::fs::write(&input, &data).unwrap();
    for codec in ["qlc", "huffman", "elias-gamma", "raw"] {
        let framed = dir.join(format!("{codec}.qlf"));
        let restored = dir.join(format!("{codec}.out"));
        let out = qlc()
            .args([
                "compress",
                input.to_str().unwrap(),
                framed.to_str().unwrap(),
                "--codec",
                codec,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{codec}: {:?}", out);
        let out = qlc()
            .args([
                "decompress",
                framed.to_str().unwrap(),
                restored.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{codec}");
        assert_eq!(std::fs::read(&restored).unwrap(), data, "{codec}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn adaptive_chunks_cli_roundtrip_and_validation() {
    let dir = tmp("adaptive");
    let input = dir.join("in.bin");
    // A stream that drifts hard at the midpoint, so at least one chunk
    // re-fits its tables.
    let mut data: Vec<u8> = (0..80_000u64)
        .map(|i| (i.wrapping_mul(i) % 97 % 64) as u8)
        .collect();
    let tail: Vec<u8> = data.iter().map(|&s| 255 - s).collect();
    data.extend_from_slice(&tail);
    std::fs::write(&input, &data).unwrap();
    let framed = dir.join("out.qlf");
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            framed.to_str().unwrap(),
            "--codec",
            "qlc",
            "--adaptive-chunks",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    // Bit-exact roundtrip on every decode path.
    for mode in ["batched", "scalar", "lanes"] {
        let restored = dir.join(format!("out.{mode}"));
        let out = qlc()
            .args([
                "decompress",
                framed.to_str().unwrap(),
                restored.to_str().unwrap(),
                "--decode",
                mode,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{mode}: {out:?}");
        assert_eq!(std::fs::read(&restored).unwrap(), data, "{mode}");
    }
    // Adaptive chunks need a per-chunk-table codec family…
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            framed.to_str().unwrap(),
            "--codec",
            "huffman",
            "--adaptive-chunks",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--adaptive-chunks + huffman must fail");
    // …and a chunked QLF2 frame.
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            framed.to_str().unwrap(),
            "--codec",
            "qlc",
            "--qlf1",
            "--adaptive-chunks",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--adaptive-chunks + --qlf1 must fail");
    // Unknown decode mode is a clean CLI error.
    let out = qlc()
        .args([
            "decompress",
            framed.to_str().unwrap(),
            dir.join("x").to_str().unwrap(),
            "--decode",
            "quantum",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sharded_compress_decompress_roundtrip() {
    let dir = tmp("sharded");
    let input = dir.join("in.bin");
    let data: Vec<u8> = (0..60_000u64)
        .map(|i| (i.wrapping_mul(7 * i + 3) % 89 % 48) as u8)
        .collect();
    std::fs::write(&input, &data).unwrap();
    let manifest = dir.join("out.qlm");
    let restored = dir.join("out.bin");
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            manifest.to_str().unwrap(),
            "--codec",
            "qlc",
            "--shards",
            "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    assert!(manifest.exists());
    for k in 0..3 {
        assert!(dir.join(format!("out.qlm.shard{k}")).exists(), "shard {k}");
    }
    let out = qlc()
        .args([
            "decompress",
            manifest.to_str().unwrap(),
            restored.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    assert_eq!(std::fs::read(&restored).unwrap(), data);
    // Legacy single-payload frames and shard sets are exclusive.
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            manifest.to_str().unwrap(),
            "--qlf1",
            "--shards",
            "2",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--qlf1 --shards must conflict");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn collective_fabric_presets() {
    for fabric in ["pod", "superpod", "ethernet"] {
        let out = qlc()
            .args([
                "collective", "--op", "allreduce", "--workers", "4",
                "--size", "16384", "--codec", "huffman", "--fabric", fabric,
                "--json",
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{fabric}: {out:?}");
        let text = String::from_utf8_lossy(&out.stdout);
        let json = qlc::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(
            json.get("fabric").and_then(|j| j.as_str()),
            Some(fabric)
        );
        let total = json.get("total_time_s").unwrap().as_f64().unwrap();
        let pipelined =
            json.get("pipelined_time_s").unwrap().as_f64().unwrap();
        assert!(
            pipelined <= total * (1.0 + 1e-9),
            "{fabric}: {pipelined} > {total}"
        );
    }
    // Unknown preset is a clean CLI error.
    let out = qlc()
        .args(["collective", "--fabric", "carrier-pigeon"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn tables_emit_paper_schemes() {
    let out = qlc()
        .args(["tables", "--table", "1", "--scale", "18", "--seed", "1"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("TAB1"));
    assert!(text.contains("168")); // area 8 size from the paper
    assert!(text.contains("compressibility"));
}

#[test]
fn tables_json_is_parseable() {
    let out = qlc()
        .args(["tables", "--fig", "1", "--scale", "18", "--json"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    qlc::util::json::Json::parse(text.trim()).unwrap();
}

#[test]
fn analyze_reports_entropy() {
    let out = qlc()
        .args(["analyze", "--kind", "ffn2_act", "--n", "65536"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("entropy"));
    assert!(text.contains("huffman"));
    assert!(text.contains("qlc"));
}

#[test]
fn datagen_then_analyze_trace() {
    let dir = tmp("datagen");
    let out = qlc()
        .args([
            "datagen",
            "--kind",
            "ffn1_act",
            "--n",
            "65536",
            "--out",
            dir.to_str().unwrap(),
            "--seed",
            "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    assert!(dir.join("ffn1_act.syms").exists());
    let out = qlc()
        .args([
            "analyze",
            "--dir",
            dir.to_str().unwrap(),
            "--name",
            "ffn1_act",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn optimize_prints_scheme() {
    let out = qlc()
        .args(["optimize", "--kind", "ffn2_act", "--n", "65536"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("OPTIMIZED"));
    assert!(text.contains("Area"));
}

#[test]
fn collective_reports_ratio() {
    let out = qlc()
        .args([
            "collective",
            "--op",
            "allreduce",
            "--workers",
            "4",
            "--size",
            "16384",
            "--codec",
            "qlc",
            "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    let text = String::from_utf8_lossy(&out.stdout);
    let json = qlc::util::json::Json::parse(text.trim()).unwrap();
    assert!(
        json.get("compression_ratio").unwrap().as_f64().unwrap() > 1.0
    );
}

#[test]
fn serve_runs_pipeline() {
    let out = qlc()
        .args([
            "serve", "--codec", "qlc", "--workers", "2", "--n", "1048576",
            "--chunk", "65536",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("compressibility"));
}
