//! CLI smoke tests: drive the `qlc` binary end-to-end through its
//! subcommands (compress/decompress file roundtrip, tables, entropy,
//! optimize, collective, datagen, and the `analyze` source linter).

use std::path::PathBuf;
use std::process::Command;

fn qlc() -> Command {
    Command::new(env!("CARGO_BIN_EXE_qlc"))
}

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("qlc-cli-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn help_lists_subcommands() {
    let out = qlc().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in
        ["tables", "compress", "collective", "hw", "serve", "analyze",
         "entropy", "pipeline", "call", "loadgen"]
    {
        assert!(text.contains(cmd), "{cmd} missing from help");
    }
}

#[test]
fn unknown_subcommand_fails() {
    let out = qlc().arg("wat").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn compress_decompress_file_roundtrip() {
    let dir = tmp("roundtrip");
    let input = dir.join("in.bin");
    // Skewed but not degenerate content.
    let data: Vec<u8> = (0..100_000u64)
        .map(|i| (i.wrapping_mul(i) % 97 % 64) as u8)
        .collect();
    std::fs::write(&input, &data).unwrap();
    for codec in ["qlc", "huffman", "elias-gamma", "raw"] {
        let framed = dir.join(format!("{codec}.qlf"));
        let restored = dir.join(format!("{codec}.out"));
        let out = qlc()
            .args([
                "compress",
                input.to_str().unwrap(),
                framed.to_str().unwrap(),
                "--codec",
                codec,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{codec}: {:?}", out);
        let out = qlc()
            .args([
                "decompress",
                framed.to_str().unwrap(),
                restored.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{codec}");
        assert_eq!(std::fs::read(&restored).unwrap(), data, "{codec}");
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn adaptive_chunks_cli_roundtrip_and_validation() {
    let dir = tmp("adaptive");
    let input = dir.join("in.bin");
    // A stream that drifts hard at the midpoint, so at least one chunk
    // re-fits its tables.
    let mut data: Vec<u8> = (0..80_000u64)
        .map(|i| (i.wrapping_mul(i) % 97 % 64) as u8)
        .collect();
    let tail: Vec<u8> = data.iter().map(|&s| 255 - s).collect();
    data.extend_from_slice(&tail);
    std::fs::write(&input, &data).unwrap();
    let framed = dir.join("out.qlf");
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            framed.to_str().unwrap(),
            "--codec",
            "qlc",
            "--adaptive-chunks",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    // Bit-exact roundtrip on every decode path.
    for mode in ["batched", "scalar", "lanes"] {
        let restored = dir.join(format!("out.{mode}"));
        let out = qlc()
            .args([
                "decompress",
                framed.to_str().unwrap(),
                restored.to_str().unwrap(),
                "--decode",
                mode,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{mode}: {out:?}");
        assert_eq!(std::fs::read(&restored).unwrap(), data, "{mode}");
    }
    // Adaptive chunks need a per-chunk-table codec family…
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            framed.to_str().unwrap(),
            "--codec",
            "huffman",
            "--adaptive-chunks",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--adaptive-chunks + huffman must fail");
    // …and a chunked QLF2 frame.
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            framed.to_str().unwrap(),
            "--codec",
            "qlc",
            "--qlf1",
            "--adaptive-chunks",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--adaptive-chunks + --qlf1 must fail");
    // Unknown decode mode is a clean CLI error.
    let out = qlc()
        .args([
            "decompress",
            framed.to_str().unwrap(),
            dir.join("x").to_str().unwrap(),
            "--decode",
            "quantum",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn encode_modes_cli_write_identical_frames() {
    let dir = tmp("encmodes");
    let input = dir.join("in.bin");
    let data: Vec<u8> = (0..60_000u64)
        .map(|i| (i.wrapping_mul(3 * i + 5) % 101 % 64) as u8)
        .collect();
    std::fs::write(&input, &data).unwrap();
    // All three encode paths must write bit-identical frames, and the
    // frame must roundtrip.
    let mut frames = Vec::new();
    for mode in ["batched", "scalar", "lanes"] {
        let framed = dir.join(format!("out.{mode}.qlf"));
        let out = qlc()
            .args([
                "compress",
                input.to_str().unwrap(),
                framed.to_str().unwrap(),
                "--codec",
                "qlc",
                "--encode",
                mode,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{mode}: {out:?}");
        frames.push(std::fs::read(&framed).unwrap());
    }
    assert_eq!(frames[0], frames[1], "batched vs scalar");
    assert_eq!(frames[0], frames[2], "batched vs lanes");
    let restored = dir.join("out.bin");
    let out = qlc()
        .args([
            "decompress",
            dir.join("out.lanes.qlf").to_str().unwrap(),
            restored.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    assert_eq!(std::fs::read(&restored).unwrap(), data);
    // Unknown encode mode is a clean CLI error.
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            dir.join("x.qlf").to_str().unwrap(),
            "--codec",
            "qlc",
            "--encode",
            "quantum",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success());
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sharded_compress_decompress_roundtrip() {
    let dir = tmp("sharded");
    let input = dir.join("in.bin");
    let data: Vec<u8> = (0..60_000u64)
        .map(|i| (i.wrapping_mul(7 * i + 3) % 89 % 48) as u8)
        .collect();
    std::fs::write(&input, &data).unwrap();
    let manifest = dir.join("out.qlm");
    let restored = dir.join("out.bin");
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            manifest.to_str().unwrap(),
            "--codec",
            "qlc",
            "--shards",
            "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    assert!(manifest.exists());
    for k in 0..3 {
        assert!(dir.join(format!("out.qlm.shard{k}")).exists(), "shard {k}");
    }
    let out = qlc()
        .args([
            "decompress",
            manifest.to_str().unwrap(),
            restored.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    assert_eq!(std::fs::read(&restored).unwrap(), data);
    // Legacy single-payload frames and shard sets are exclusive.
    let out = qlc()
        .args([
            "compress",
            input.to_str().unwrap(),
            manifest.to_str().unwrap(),
            "--qlf1",
            "--shards",
            "2",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--qlf1 --shards must conflict");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn collective_fabric_presets() {
    for fabric in ["pod", "superpod", "ethernet"] {
        let out = qlc()
            .args([
                "collective", "--op", "allreduce", "--workers", "4",
                "--size", "16384", "--codec", "huffman", "--fabric", fabric,
                "--json",
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{fabric}: {out:?}");
        let text = String::from_utf8_lossy(&out.stdout);
        let json = qlc::util::json::Json::parse(text.trim()).unwrap();
        assert_eq!(
            json.get("fabric").and_then(|j| j.as_str()),
            Some(fabric)
        );
        let total = json.get("total_time_s").unwrap().as_f64().unwrap();
        let pipelined =
            json.get("pipelined_time_s").unwrap().as_f64().unwrap();
        assert!(
            pipelined <= total * (1.0 + 1e-9),
            "{fabric}: {pipelined} > {total}"
        );
    }
    // Unknown preset is a clean CLI error.
    let out = qlc()
        .args(["collective", "--fabric", "carrier-pigeon"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn tables_emit_paper_schemes() {
    let out = qlc()
        .args(["tables", "--table", "1", "--scale", "18", "--seed", "1"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("TAB1"));
    assert!(text.contains("168")); // area 8 size from the paper
    assert!(text.contains("compressibility"));
}

#[test]
fn tables_json_is_parseable() {
    let out = qlc()
        .args(["tables", "--fig", "1", "--scale", "18", "--json"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    qlc::util::json::Json::parse(text.trim()).unwrap();
}

#[test]
fn entropy_reports_codec_comparison() {
    let out = qlc()
        .args(["entropy", "--kind", "ffn2_act", "--n", "65536"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("entropy"));
    assert!(text.contains("huffman"));
    assert!(text.contains("qlc"));
}

#[test]
fn datagen_then_entropy_trace() {
    let dir = tmp("datagen");
    let out = qlc()
        .args([
            "datagen",
            "--kind",
            "ffn1_act",
            "--n",
            "65536",
            "--out",
            dir.to_str().unwrap(),
            "--seed",
            "3",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    assert!(dir.join("ffn1_act.syms").exists());
    let out = qlc()
        .args([
            "entropy",
            "--dir",
            dir.to_str().unwrap(),
            "--name",
            "ffn1_act",
        ])
        .output()
        .unwrap();
    assert!(out.status.success());
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The committed baseline must keep the crate's own tree clean: a new
/// finding anywhere in `src/` fails this test (and the CI analyze job)
/// until it is fixed, waived with a reasoned `// lint:` comment, or
/// consciously re-baselined.
#[test]
fn analyze_is_clean_against_the_committed_baseline() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let out = qlc()
        .args([
            "analyze",
            "--src",
            manifest.join("src").to_str().unwrap(),
            "--baseline",
            manifest.join("analysis/baseline.txt").to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        out.status.success(),
        "qlc analyze found new findings:\n{stdout}\n{stderr}"
    );
    assert!(stdout.contains("0 new"), "{stdout}");
}

/// Seeding each of the five rule classes into a fresh wire-scope
/// module must make `analyze` exit non-zero and name every rule; after
/// `--update-baseline` the same tree passes.
#[test]
fn analyze_flags_seeded_violations_and_baseline_grandfathers() {
    let dir = tmp("analyze");
    let net = dir.join("src/transport/net");
    std::fs::create_dir_all(&net).unwrap();
    std::fs::write(
        net.join("seeded.rs"),
        concat!(
            "pub fn narrow(n: usize, out: &mut Vec<u8>) {\n",
            "    out.extend_from_slice(&(n as u32).to_le_bytes());\n",
            "}\n",
            "pub fn alloc(len: usize) -> Vec<u8> {\n",
            "    Vec::with_capacity(len)\n",
            "}\n",
            "pub fn boom(v: Option<u8>) -> u8 {\n",
            "    v.unwrap()\n",
            "}\n",
            "pub unsafe fn danger(p: *const u8) -> u8 {\n",
            "    unsafe { *p }\n",
            "}\n",
            "pub fn forbidden(x: i8) -> u8 {\n",
            "    unsafe { std::mem::transmute(x) }\n",
            "}\n",
        ),
    )
    .unwrap();
    let src = dir.join("src");
    let baseline = dir.join("analysis/baseline.txt");
    let run = |extra: &[&str]| {
        let mut args = vec![
            "analyze",
            "--src",
            src.to_str().unwrap(),
            "--baseline",
            baseline.to_str().unwrap(),
        ];
        args.extend_from_slice(extra);
        qlc().args(&args).output().unwrap()
    };

    let out = run(&["--deny-new"]);
    assert!(!out.status.success(), "seeded violations must fail");
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    for rule in [
        "unchecked-narrowing",
        "cap-before-alloc",
        "panic-free",
        "safety-comment",
        "forbidden-construct",
    ] {
        assert!(text.contains(rule), "{rule} missing from:\n{text}");
    }
    assert!(
        text.contains("src/transport/net/seeded.rs:"),
        "findings must carry file:line labels:\n{text}"
    );

    let out = run(&["--update-baseline"]);
    assert!(out.status.success(), "{out:?}");
    let out = run(&[]);
    assert!(
        out.status.success(),
        "baselined findings must be grandfathered: {out:?}"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The v2 acceptance shapes: a cap check on the *wrong* variable, a
/// tainted loop bound, tainted length arithmetic, and a register
/// without deregister on an early-return path must all flag — and
/// their sanitized twins must pass with zero findings.
#[test]
fn analyze_taint_rules_flag_seeded_shapes_and_accept_twins() {
    let dir = tmp("taint");
    let net = dir.join("src/transport/net");
    let serve = dir.join("src/serve");
    std::fs::create_dir_all(&net).unwrap();
    std::fs::create_dir_all(&serve).unwrap();
    std::fs::write(
        net.join("taint_seeded.rs"),
        concat!(
            "pub struct Hdr { pub n_scales: usize, pub payload_len: usize }\n",
            "pub fn wrong_cap(hdr: &Hdr) -> Vec<u8> {\n",
            "    if hdr.n_scales > 1024 {\n",
            "        return Vec::new();\n",
            "    }\n",
            "    vec![0u8; hdr.payload_len]\n",
            "}\n",
            "pub fn loop_bound(n_chunks: usize) {\n",
            "    for _ in 0..n_chunks {\n",
            "        let _ = n_chunks;\n",
            "    }\n",
            "}\n",
            "pub fn arith(n_rows: usize, row_len: usize, out: &mut Vec<u8>) {\n",
            "    let total = n_rows * row_len;\n",
            "    out.reserve(total);\n",
            "}\n",
        ),
    )
    .unwrap();
    std::fs::write(
        serve.join("leaky.rs"),
        concat!(
            "pub fn open(r: &mut Reactor, fd: i32) -> Result<(), String> {\n",
            "    r.register(fd, 0, 1)?;\n",
            "    probe()?;\n",
            "    r.deregister(fd)?;\n",
            "    Ok(())\n",
            "}\n",
        ),
    )
    .unwrap();
    let out = qlc()
        .args([
            "analyze",
            "--src",
            dir.join("src").to_str().unwrap(),
            "--baseline",
            dir.join("analysis/baseline.txt").to_str().unwrap(),
            "--deny-new",
        ])
        .output()
        .unwrap();
    assert!(!out.status.success(), "seeded taint shapes must fail");
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    for rule in [
        "cap-before-alloc",
        "tainted-loop-bound",
        "tainted-length-arith",
        "reactor-interest-leak",
    ] {
        assert!(text.contains(rule), "{rule} missing from:\n{text}");
    }
    // Findings carry the source-to-sink chain, not just a line.
    assert!(
        text.contains("wire-shaped") && text.contains("reaches"),
        "taint chain missing from:\n{text}"
    );
    assert!(
        text.contains("flows into `total`"),
        "arith chain must name the intermediate binding:\n{text}"
    );

    // Sanitized twins of all four shapes: zero findings.
    std::fs::remove_file(net.join("taint_seeded.rs")).unwrap();
    std::fs::remove_file(serve.join("leaky.rs")).unwrap();
    std::fs::write(
        net.join("taint_clean.rs"),
        concat!(
            "pub struct Hdr { pub n_scales: usize, pub payload_len: usize }\n",
            "pub fn right_cap(hdr: &Hdr) -> Vec<u8> {\n",
            "    if hdr.payload_len > 4096 {\n",
            "        return Vec::new();\n",
            "    }\n",
            "    vec![0u8; hdr.payload_len]\n",
            "}\n",
            "pub fn loop_capped(n_chunks: usize) {\n",
            "    if n_chunks > 64 {\n",
            "        return;\n",
            "    }\n",
            "    for _ in 0..n_chunks {\n",
            "        let _ = n_chunks;\n",
            "    }\n",
            "}\n",
            "pub fn arith_checked(\n",
            "    n_rows: usize,\n",
            "    row_len: usize,\n",
            "    out: &mut Vec<u8>,\n",
            ") -> Result<(), String> {\n",
            "    let total = n_rows.checked_mul(row_len).ok_or(\"overflow\")?;\n",
            "    if total > 4096 {\n",
            "        return Err(\"cap\".into());\n",
            "    }\n",
            "    out.reserve(total);\n",
            "    Ok(())\n",
            "}\n",
        ),
    )
    .unwrap();
    std::fs::write(
        serve.join("balanced.rs"),
        concat!(
            "pub fn open(r: &mut Reactor, fd: i32) -> Result<(), String> {\n",
            "    r.register(fd, 0, 1)?;\n",
            "    if probe().is_err() {\n",
            "        let _ = r.deregister(fd);\n",
            "        return Err(\"probe\".into());\n",
            "    }\n",
            "    r.deregister(fd)?;\n",
            "    Ok(())\n",
            "}\n",
        ),
    )
    .unwrap();
    let out = qlc()
        .args([
            "analyze",
            "--src",
            dir.join("src").to_str().unwrap(),
            "--baseline",
            dir.join("analysis/baseline.txt").to_str().unwrap(),
            "--deny-new",
        ])
        .output()
        .unwrap();
    let text = String::from_utf8_lossy(&out.stdout).to_string();
    assert!(out.status.success(), "sanitized twins must pass:\n{text}");
    assert!(text.contains("0 new"), "{text}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `--json` must emit a parseable report whose counts agree with the
/// text run over the same tree.
#[test]
fn analyze_json_report_parses_and_matches_text() {
    use qlc::util::json::Json;
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let src = manifest.join("src");
    let base = manifest.join("analysis/baseline.txt");
    let run = |extra: &[&str]| {
        let mut args = vec![
            "analyze",
            "--src",
            src.to_str().unwrap(),
            "--baseline",
            base.to_str().unwrap(),
        ];
        args.extend_from_slice(extra);
        qlc().args(&args).output().unwrap()
    };
    let text_out = run(&[]);
    assert!(text_out.status.success());
    let text = String::from_utf8_lossy(&text_out.stdout).to_string();

    let json_out = run(&["--json"]);
    assert!(json_out.status.success());
    let report =
        Json::parse(&String::from_utf8_lossy(&json_out.stdout)).unwrap();
    assert_eq!(report.get("version").unwrap().as_usize(), Some(2));
    let counts = report.get("counts").unwrap();
    let total = counts.get("total").unwrap().as_usize().unwrap();
    let baselined = counts.get("baselined").unwrap().as_usize().unwrap();
    let fresh = counts.get("new").unwrap().as_usize().unwrap();
    assert_eq!(fresh, 0, "committed tree must be clean");
    assert!(text.contains(&format!(
        "qlc analyze: {total} file finding(s), {baselined} baselined, \
         {fresh} new"
    )));
    assert_eq!(
        report.get("findings").unwrap().as_arr().unwrap().len(),
        total
    );
    // Every reported rule name is a registered rule.
    let rules: Vec<&str> = report
        .get("rules")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|r| r.as_str().unwrap())
        .collect();
    for f in report.get("findings").unwrap().as_arr().unwrap() {
        let rule = f.get("rule").unwrap().as_str().unwrap();
        assert!(rules.contains(&rule), "unregistered rule {rule}");
    }
}

/// A baseline entry matching no finding warns by default and fails
/// under `--deny-stale`.
#[test]
fn analyze_stale_baseline_warns_then_denies() {
    let dir = tmp("stale");
    std::fs::create_dir_all(dir.join("src")).unwrap();
    std::fs::write(dir.join("src/ok.rs"), "pub fn ok() -> u8 { 0 }\n")
        .unwrap();
    std::fs::create_dir_all(dir.join("analysis")).unwrap();
    std::fs::write(
        dir.join("analysis/baseline.txt"),
        "src/gone.rs:7: panic-free: '.unwrap()' fixed long ago\n",
    )
    .unwrap();
    let run = |extra: &[&str]| {
        let mut args = vec![
            "analyze",
            "--src",
            dir.join("src").to_str().unwrap(),
            "--baseline",
            dir.join("analysis/baseline.txt").to_str().unwrap(),
        ];
        args.extend_from_slice(extra);
        qlc().args(&args).output().unwrap()
    };
    let out = run(&[]);
    assert!(out.status.success(), "stale is a warning by default");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(
        stderr.contains("stale baseline entry")
            && stderr.contains("src/gone.rs:7"),
        "missing stale warning: {stderr}"
    );
    let out = run(&["--deny-stale"]);
    assert!(!out.status.success(), "--deny-stale must fail");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("stale baseline"), "{stderr}");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// `--explain` documents every registered rule (kept in sync by
/// iterating the registry here) and rejects unknown rule names.
#[test]
fn analyze_explain_covers_every_registered_rule() {
    let out = qlc()
        .args(["analyze", "--explain", "all"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for rule in &qlc::analysis::rules::RULES {
        assert!(text.contains(rule.name), "{} missing", rule.name);
        assert!(
            text.contains(rule.contract),
            "{} contract missing",
            rule.name
        );
    }
    assert!(text.contains("waiver:"), "{text}");
    assert!(text.contains("example:"), "{text}");

    let one = qlc()
        .args(["analyze", "--explain", "tainted-loop-bound"])
        .output()
        .unwrap();
    assert!(one.status.success());
    let text = String::from_utf8_lossy(&one.stdout);
    assert!(text.contains("tainted-loop-bound"));
    assert!(!text.contains("unchecked-narrowing"));

    let bad = qlc()
        .args(["analyze", "--explain", "no-such-rule"])
        .output()
        .unwrap();
    assert!(!bad.status.success());
    let err = String::from_utf8_lossy(&bad.stderr);
    assert!(err.contains("known rules"), "{err}");
}

#[test]
fn optimize_prints_scheme() {
    let out = qlc()
        .args(["optimize", "--kind", "ffn2_act", "--n", "65536"])
        .output()
        .unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("OPTIMIZED"));
    assert!(text.contains("Area"));
}

#[test]
fn collective_reports_ratio() {
    let out = qlc()
        .args([
            "collective",
            "--op",
            "allreduce",
            "--workers",
            "4",
            "--size",
            "16384",
            "--codec",
            "qlc",
            "--json",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    let text = String::from_utf8_lossy(&out.stdout);
    let json = qlc::util::json::Json::parse(text.trim()).unwrap();
    assert!(
        json.get("compression_ratio").unwrap().as_f64().unwrap() > 1.0
    );
}

#[test]
fn collective_writes_trace_and_metrics() {
    let dir = tmp("obs");
    let trace = dir.join("trace.json");
    let metrics_txt = dir.join("metrics.txt");
    let metrics_json = dir.join("metrics.json");
    for metrics in [&metrics_txt, &metrics_json] {
        let out = qlc()
            .args([
                "collective", "--op", "allreduce", "--workers", "4",
                "--size", "16384", "--codec", "qlc", "--json", "--trace",
                trace.to_str().unwrap(), "--metrics",
                metrics.to_str().unwrap(),
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "{out:?}");
        // --json stdout must stay pure JSON (status lines go to stderr).
        let text = String::from_utf8_lossy(&out.stdout);
        qlc::util::json::Json::parse(text.trim()).unwrap();
    }
    // The trace is a Chrome trace-event object with real span events.
    let trace_doc = qlc::util::json::Json::parse(
        &std::fs::read_to_string(&trace).unwrap(),
    )
    .unwrap();
    let events = trace_doc
        .get("traceEvents")
        .and_then(|e| e.as_arr())
        .expect("traceEvents array");
    assert!(
        events.iter().any(|e| {
            e.get("ph").and_then(|p| p.as_str()) == Some("X")
        }),
        "trace has no duration events"
    );
    // .txt gets the Prometheus exposition, .json the mergeable snapshot.
    let prom = std::fs::read_to_string(&metrics_txt).unwrap();
    assert!(prom.contains("_total"), "{prom}");
    let snap = qlc::obs::Snapshot::parse(
        &std::fs::read_to_string(&metrics_json).unwrap(),
    )
    .unwrap();
    assert!(
        snap.counters.keys().any(|k| k.starts_with("transport_")),
        "snapshot missing transport counters: {:?}",
        snap.counters.keys().collect::<Vec<_>>()
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pipeline_demo_runs() {
    let out = qlc()
        .args([
            "pipeline", "--codec", "qlc", "--workers", "2", "--n",
            "1048576", "--chunk", "65536",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{out:?}");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("compressibility"));
}
